"""Block assembly and layer stacks for every architecture family.

Layers are organized into *groups*: ``group_layout(cfg)`` returns the
static tuple of block kinds that make up one group, and the full network
is ``num_groups(cfg)`` repetitions scanned with ``lax.scan`` (single
trace per group -> fast compiles at 30-50 layer depth).  Examples:

  qwen2     -> ("attn:full",) x 28 groups
  mixtral   -> ("moe:swa",) x 32
  gemma2    -> ("attn:swa", "attn:full") x 23   (local/global alternation)
  zamba2    -> ("shared_attn", "mamba" x 6) x 9 (shared-params attn block)
  rwkv6     -> ("rwkv",) x 32
  whisper   -> encoder ("enc_attn",) x 12 + decoder ("dec_attn",) x 12

Block kinds carry their attention window statically, so the banded /
rect / direct attention paths stay structurally fixed inside the scan.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .config import LMConfig
from . import layers as L
from . import moe as M
from . import rwkv as R
from . import ssm as S
from .sharding_ctx import constrain


# --------------------------------------------------------------------------
# group layout
# --------------------------------------------------------------------------

def group_layout(cfg: LMConfig) -> Tuple[str, ...]:
    if cfg.family == "dense" or cfg.family == "vlm":
        if cfg.attn_kind == "local_global":
            return ("attn:swa", "attn:full")
        if cfg.attn_kind == "swa":
            return ("attn:swa",)
        return ("attn:full",)
    if cfg.family == "moe":
        return ("moe:swa",) if cfg.attn_kind == "swa" else ("moe:full",)
    if cfg.family == "rwkv":
        return ("rwkv",)
    if cfg.family == "hybrid":
        return ("shared_attn",) + ("mamba",) * cfg.shared_attn_every
    if cfg.family == "encdec":
        return ("dec_attn",)
    raise ValueError(cfg.family)


def num_groups(cfg: LMConfig) -> int:
    lay = group_layout(cfg)
    per = len([k for k in lay if k != "shared_attn"]) or 1
    if cfg.family == "hybrid":
        assert cfg.num_layers % cfg.shared_attn_every == 0
        return cfg.num_layers // cfg.shared_attn_every
    assert cfg.num_layers % per == 0
    return cfg.num_layers // per


def _kind_window(cfg: LMConfig, kind: str) -> Optional[int]:
    return cfg.window if kind.endswith(":swa") else None


# --------------------------------------------------------------------------
# per-kind params / cache / forward
# --------------------------------------------------------------------------

def block_params(cfg: LMConfig, kind: str, key) -> dict:
    ks = L.split(key, 6)
    if kind.startswith("attn:") or kind == "enc_attn":
        return {"ln1": L.norm_params(cfg), "attn": L.attn_params(cfg, ks[0]),
                "ln2": L.norm_params(cfg), "mlp": L.mlp_params(cfg, ks[1])}
    if kind.startswith("moe:"):
        p = {"ln1": L.norm_params(cfg), "attn": L.attn_params(cfg, ks[0]),
             "ln2": L.norm_params(cfg), "moe": M.moe_params(cfg, ks[1])}
        if cfg.moe.dense_residual:
            p["mlp"] = L.mlp_params(cfg, ks[2])
        return p
    if kind == "rwkv":
        return {"ln1": L.norm_params(cfg),
                "tm": R.rwkv_time_mix_params(cfg, ks[0]),
                "ln2": L.norm_params(cfg),
                "cm": R.rwkv_channel_mix_params(cfg, ks[1])}
    if kind == "mamba":
        return {"ln": L.norm_params(cfg), "mamba": S.mamba_params(cfg, ks[0])}
    if kind == "shared_attn":
        return {}                      # actual params live at params["shared"]
    if kind == "dec_attn":
        return {"ln1": L.norm_params(cfg), "attn": L.attn_params(cfg, ks[0]),
                "ln_x": L.norm_params(cfg), "xattn": L.attn_params(cfg, ks[1]),
                "ln2": L.norm_params(cfg), "mlp": L.mlp_params(cfg, ks[2])}
    raise ValueError(kind)


def init_block_cache(cfg: LMConfig, kind: str, batch: int, max_len: int,
                     dtype) -> dict:
    KV, Dh = cfg.num_kv_heads, cfg.head_dim
    d = cfg.d_model

    def kv_cache(window):
        S_c = max_len if window is None else min(max_len, window)
        return {"k": jnp.zeros((batch, KV, S_c, Dh), dtype),
                "v": jnp.zeros((batch, KV, S_c, Dh), dtype)}

    if kind.startswith("attn:") or kind.startswith("moe:"):
        return kv_cache(_kind_window(cfg, kind))
    if kind == "shared_attn":
        return kv_cache(None)
    if kind == "rwkv":
        H = cfg.num_heads
        Dh_r = d // H
        return {"wkv": jnp.zeros((batch, H, Dh_r, Dh_r), jnp.float32),
                "shift_tm": jnp.zeros((batch, d), dtype),
                "shift_cm": jnp.zeros((batch, d), dtype)}
    if kind == "mamba":
        ch = cfg.d_inner + 2 * cfg.ssm_state
        return {"ssm": jnp.zeros((batch, cfg.n_ssm_heads, cfg.ssm_state,
                                  cfg.d_inner // cfg.n_ssm_heads), jnp.float32),
                "conv": jnp.zeros((batch, cfg.conv_width - 1, ch), dtype)}
    if kind == "dec_attn":
        c = kv_cache(None)
        c["xk"] = jnp.zeros((batch, KV, cfg.enc_seq, Dh), dtype)
        c["xv"] = jnp.zeros((batch, KV, cfg.enc_seq, Dh), dtype)
        return c
    raise ValueError(kind)


def block_forward(cfg: LMConfig, kind: str, p: dict, x: jnp.ndarray,
                  freqs: jnp.ndarray, cache: Optional[dict],
                  shared: Optional[dict] = None,
                  enc_out: Optional[jnp.ndarray] = None):
    """One block. Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if kind == "shared_attn":
        p = shared
        kind = "attn:full"
        # falls through to the attention path with full-window KV
    if kind.startswith("attn:") or kind.startswith("moe:") or kind == "enc_attn":
        window = _kind_window(cfg, kind)
        kv_cache = None if cache is None else \
            {"k": cache["k"], "v": cache["v"], "pos": cache["pos"]}
        h = L.apply_norm(cfg, p["ln1"], x)
        causal_kind = kind != "enc_attn"
        if causal_kind:
            a, new_kv = L.attn_forward(cfg, p["attn"], h, freqs,
                                       window=window, cache=kv_cache)
        else:
            a, new_kv = _noncausal_self_attn(cfg, p["attn"], h)
        x = constrain(x + a, "res")
        h = L.apply_norm(cfg, p["ln2"], x)
        if kind.startswith("moe:"):
            y, aux = M.moe_forward(cfg, p["moe"], h)
            if cfg.moe.dense_residual:
                y = y + L.mlp_forward(cfg, p["mlp"], h)
        else:
            y = L.mlp_forward(cfg, p["mlp"], h)
        x = constrain(x + y, "res")
        new_cache = None
        if cache is not None and new_kv is not None:
            new_cache = dict(cache)
            new_cache.update({"k": new_kv["k"], "v": new_kv["v"]})
        return x, new_cache, aux
    if kind == "rwkv":
        st_tm = None if cache is None else \
            {"wkv": cache["wkv"], "shift": cache["shift_tm"]}
        h = L.apply_norm(cfg, p["ln1"], x)
        a, new_tm = R.rwkv_time_mix(cfg, p["tm"], h, st_tm)
        x = constrain(x + a, "res")
        st_cm = None if cache is None else {"shift": cache["shift_cm"]}
        h = L.apply_norm(cfg, p["ln2"], x)
        y, new_cm = R.rwkv_channel_mix(cfg, p["cm"], h, st_cm)
        x = constrain(x + y, "res")
        new_cache = None
        if cache is not None:
            new_cache = {"wkv": new_tm["wkv"], "shift_tm": new_tm["shift"],
                         "shift_cm": new_cm["shift"]}
        return x, new_cache, aux
    if kind == "mamba":
        st = None if cache is None else \
            {"ssm": cache["ssm"], "conv": cache["conv"]}
        h = L.apply_norm(cfg, p["ln"], x)
        y, new_st = S.mamba_forward(cfg, p["mamba"], h, st)
        x = constrain(x + y, "res")
        return x, new_st, aux
    if kind == "dec_attn":
        kv_cache = None if cache is None else \
            {"k": cache["k"], "v": cache["v"], "pos": cache["pos"]}
        h = L.apply_norm(cfg, p["ln1"], x)
        a, new_kv = L.attn_forward(cfg, p["attn"], h, freqs, window=None,
                                   cache=kv_cache)
        x = constrain(x + a, "res")
        h = L.apply_norm(cfg, p["ln_x"], x)
        if cache is not None:
            xa = _cross_attn_cached(cfg, p["xattn"], h, cache["xk"], cache["xv"])
        else:
            xa = _cross_attn(cfg, p["xattn"], h, enc_out)
        x = constrain(x + xa, "res")
        h = L.apply_norm(cfg, p["ln2"], x)
        x = constrain(x + L.mlp_forward(cfg, p["mlp"], h), "res")
        new_cache = None
        if cache is not None:
            new_cache = dict(cache)
            new_cache.update({"k": new_kv["k"], "v": new_kv["v"]})
        return x, new_cache, aux
    raise ValueError(kind)


def _noncausal_self_attn(cfg: LMConfig, p: dict, x: jnp.ndarray):
    B, S, _ = x.shape
    q, k, v = L._project_qkv(cfg, p, x)
    pos = jnp.arange(S)[None, :]
    freqs = L.rope_freqs(cfg)
    q = L.apply_rope(q, pos, freqs).transpose(0, 2, 1, 3)
    k = L.apply_rope(k, pos, freqs).transpose(0, 2, 1, 3)
    v = v.transpose(0, 2, 1, 3)
    kk = L._broadcast_kv(k, cfg.q_per_kv)
    vv = L._broadcast_kv(v, cfg.q_per_kv)
    out = L.attention(q, kk, vv, causal=False, impl=cfg.attn_impl,
                      chunk=cfg.attn_chunk, logit_dtype=cfg.logit_dtype)
    out = out.transpose(0, 2, 1, 3).reshape(B, S, -1)
    return out @ p["wo"].astype(out.dtype), None


def _cross_attn(cfg: LMConfig, p: dict, x: jnp.ndarray, enc_out: jnp.ndarray):
    B, S, _ = x.shape
    H, KV, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (x @ p["wq"].astype(x.dtype)).reshape(B, S, H, Dh)
    k = (enc_out @ p["wk"].astype(x.dtype)).reshape(B, -1, KV, Dh)
    v = (enc_out @ p["wv"].astype(x.dtype)).reshape(B, -1, KV, Dh)
    return _cross_attn_core(cfg, p, q, k.transpose(0, 2, 1, 3),
                            v.transpose(0, 2, 1, 3))


def _cross_attn_cached(cfg: LMConfig, p: dict, x: jnp.ndarray,
                       xk: jnp.ndarray, xv: jnp.ndarray):
    B, S, _ = x.shape
    H, Dh = cfg.num_heads, cfg.head_dim
    q = (x @ p["wq"].astype(x.dtype)).reshape(B, S, H, Dh)
    return _cross_attn_core(cfg, p, q, xk, xv)


def _cross_attn_core(cfg, p, q, k, v):
    B, S = q.shape[0], q.shape[1]
    q = q.transpose(0, 2, 1, 3)
    kk = L._broadcast_kv(k, cfg.q_per_kv)
    vv = L._broadcast_kv(v, cfg.q_per_kv)
    out = L.attention(q, kk, vv, causal=False, impl=cfg.attn_impl,
                      chunk=cfg.attn_chunk, logit_dtype=cfg.logit_dtype)
    out = out.transpose(0, 2, 1, 3).reshape(B, S, -1)
    return out @ p["wo"].astype(out.dtype)


# --------------------------------------------------------------------------
# stacked groups + scan
# --------------------------------------------------------------------------

def stack_params(cfg: LMConfig, key, layout: Tuple[str, ...], groups: int):
    """Params for `groups` repetitions of `layout`, leaves stacked on axis 0."""
    def one_group(k):
        ks = L.split(k, len(layout))
        return tuple(block_params(cfg, kind, ki)
                     for kind, ki in zip(layout, ks))
    return jax.vmap(one_group)(jnp.stack(L.split(key, groups)))


def stack_forward(cfg: LMConfig, stacked, x: jnp.ndarray,
                  layout: Tuple[str, ...], *,
                  cache=None, shared: Optional[dict] = None,
                  enc_out: Optional[jnp.ndarray] = None):
    """Scan `x` through all groups. cache: tuple of per-slot caches with
    leading group axis (or None). Returns (x, new_cache, aux_sum)."""
    freqs = L.rope_freqs(cfg)
    pos = None if cache is None else cache["pos"]

    def body(carry, inp):
        x, aux = carry
        gp, gc = inp
        new_slots = []
        for i, kind in enumerate(layout):
            slot_cache = None
            if gc is not None:
                slot_cache = dict(gc[i])
                slot_cache["pos"] = pos
            x, nc, a = block_forward(cfg, kind, gp[i], x, freqs, slot_cache,
                                     shared=shared, enc_out=enc_out)
            aux = aux + a
            if nc is not None:
                nc.pop("pos", None)
            new_slots.append(nc)
        return (x, aux), tuple(new_slots)

    if cfg.remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)

    aux0 = jnp.zeros((), jnp.float32)
    if cfg.scan_layers:
        xs = (stacked, cache["slots"] if cache is not None else None)
        if cache is None:
            (x, aux), new_slots = jax.lax.scan(
                lambda c, p: body(c, (p, None)), (x, aux0), stacked)
        else:
            (x, aux), new_slots = jax.lax.scan(body, (x, aux0), xs)
    else:
        G = jax.tree_util.tree_leaves(stacked)[0].shape[0]
        new_list = []
        for g in range(G):
            gp = jax.tree.map(lambda a: a[g], stacked)
            gc = None if cache is None else \
                jax.tree.map(lambda a: a[g], cache["slots"])
            (x, aux), ns = body((x, aux), (gp, gc))
            new_list.append(ns)
        new_slots = None if cache is None else \
            jax.tree.map(lambda *a: jnp.stack(a), *new_list)

    new_cache = None
    if cache is not None:
        new_cache = {"pos": pos + x.shape[1], "slots": new_slots}
    return x, new_cache, aux
