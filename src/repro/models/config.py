"""Model configuration schema shared by the whole zoo.

``LMConfig`` is a frozen (hashable) dataclass so it can ride along as a
static jit argument.  One instance fully determines parameter shapes and
the forward graph for every assigned architecture family:

  dense   -- llama-style decoder-only (qwen2, qwen1.5, stablelm, gemma2)
  moe     -- dense + mixture-of-experts FFN (mixtral, arctic)
  rwkv    -- RWKV6 "Finch" attention-free (rwkv6-3b)
  hybrid  -- Mamba2 backbone + shared attention block (zamba2)
  encdec  -- whisper-style encoder-decoder (audio frontend stubbed)
  vlm     -- ViT-frontend-stubbed decoder-only (internvl2)
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoECfg:
    num_experts: int
    top_k: int
    d_ff: int                       # per-expert hidden size
    capacity_factor: float = 1.25
    dense_residual: bool = False    # arctic: dense MLP in parallel with MoE
    router_aux_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    family: str                     # dense | moe | rwkv | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # ---- attention ----
    attn_kind: str = "full"         # full | swa | local_global | none
    window: int = 4096
    attn_softcap: Optional[float] = None
    logit_softcap: Optional[float] = None
    qkv_bias: bool = False
    rope_theta: float = 1e6
    rope_fraction: float = 1.0      # stablelm: partial rotary
    attn_impl: str = "auto"         # auto | direct | rect | tri | banded
    attn_chunk: int = 1024          # kv/q block for blocked attention

    # ---- block / mlp ----
    norm: str = "rms"               # rms | layer
    act: str = "silu"               # silu | gelu
    mlp_kind: str = "glu"           # glu | plain
    tie_embeddings: bool = False
    scale_embed: bool = False       # gemma: embed * sqrt(d_model)
    moe: Optional[MoECfg] = None

    # ---- ssm / rwkv ----
    ssm_state: int = 64
    ssm_heads: int = 0              # mamba2 value heads (0 -> derived)
    conv_width: int = 4
    expand: int = 2                 # mamba2 inner expansion
    shared_attn_every: int = 6      # zamba2: shared attn block period
    chunk_size: int = 256           # ssm / rwkv chunkwise scan length

    # ---- encoder-decoder ----
    enc_layers: int = 0
    enc_seq: int = 1500             # whisper: audio frame count

    # ---- vlm ----
    num_patches: int = 256

    # ---- numerics / compilation ----
    norm_eps: float = 1e-5
    param_dtype: str = "float32"
    dtype: str = "bfloat16"
    logit_dtype: str = "float32"    # attention/CE logit *buffer* dtype;
                                    # softmax math stays f32 (fused)
    remat: bool = True
    scan_layers: bool = True
    ce_chunk: int = 512             # sequence chunk for the CE loss
    use_flash_kernel: bool = False  # Pallas flash attention (TPU only)

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    @property
    def d_inner(self) -> int:
        """Mamba2 inner width."""
        return self.expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        if self.ssm_heads:
            return self.ssm_heads
        return self.d_inner // 64   # mamba2 default head_dim 64

    def with_overrides(self, **kw) -> "LMConfig":
        return dataclasses.replace(self, **kw)


def num_params(cfg: LMConfig) -> int:
    """Total parameter count (exact, mirrors init_params)."""
    d, ff, V = cfg.d_model, cfg.d_ff, cfg.vocab_size
    H, KV, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim

    def attn_params() -> int:
        p = d * (H * Dh) + 2 * d * (KV * Dh) + (H * Dh) * d
        if cfg.qkv_bias:
            p += H * Dh + 2 * KV * Dh
        return p

    def mlp_params(hidden: int) -> int:
        if cfg.mlp_kind == "glu":
            return 3 * d * hidden
        return 2 * d * hidden

    total = V * d                      # embedding
    if not cfg.tie_embeddings:
        total += V * d                 # output head

    if cfg.family in ("dense", "vlm"):
        per = attn_params() + mlp_params(ff) + 2 * d
        total += cfg.num_layers * per + d
    elif cfg.family == "moe":
        m = cfg.moe
        per = attn_params() + 2 * d + d * m.num_experts \
            + m.num_experts * mlp_params(m.d_ff)
        if m.dense_residual:
            per += mlp_params(ff)
        total += cfg.num_layers * per + d
    elif cfg.family == "rwkv":
        # time-mix: r,k,v,g,o (5 d*d) + decay lora + mix params + ln
        per = 5 * d * d + 2 * (d * 64 + 64 * d) + 6 * d + 2 * d + 2 * d
        # channel-mix: W_k d*ff, W_v ff*d, W_r d*d
        per += d * ff + ff * d + d * d + 2 * d
        total += cfg.num_layers * per + d
    elif cfg.family == "hybrid":
        di, ns, nh = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
        per = d * (2 * di + 2 * ns + nh) + cfg.conv_width * (di + 2 * ns) \
            + nh + nh + di * d + 2 * d + mlp_params(ff)
        total += cfg.num_layers * per
        total += attn_params() + 2 * d + d   # one shared attention block
    elif cfg.family == "encdec":
        enc_per = attn_params() + mlp_params(ff) + 2 * d
        dec_per = 2 * attn_params() + mlp_params(ff) + 3 * d
        total += cfg.enc_layers * enc_per + cfg.num_layers * dec_per + 2 * d
        total += cfg.enc_seq * d           # learned audio positions
    return total
