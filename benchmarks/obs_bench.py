"""Observability overhead benchmark: serve throughput with tracing on
vs off (the CI overhead gate).

The ``repro.obs`` contract is that a disabled tracer is a shared no-op
(zero events, zero host syncs) and an enabled tracer syncs only at
span close -- so tracing a serving stream must cost little.  This
bench replays the same BENCH_3-shaped query stream through a
:class:`~repro.serve.driver.ClusterServer` twice, tracing off then on,
best-of-``reps`` each, and reports the throughput ratio.  CI gates on
``on/off >= 0.9``.
"""

from __future__ import annotations

import time
from typing import Dict, List, Tuple

import numpy as np


def bench_obs_overhead(n: int = 20_000, scenario: str = "blobs-2d",
                       n_requests: int = 48, q_max: int = 64,
                       reps: int = 3, seed: int = 0
                       ) -> Tuple[List[Dict], float]:
    """(rows, on/off throughput ratio) for the overhead gate."""
    from repro import obs
    from repro.data.scenarios import get_scenario
    from repro.engine import cluster
    from repro.serve.driver import ClusterServer

    sc = get_scenario(scenario)
    eps = sc.eps * (sc.n / n) ** (1.0 / sc.d)
    pts = sc.points(n=n)
    res = cluster(pts, eps, sc.min_pts, engine="grit", return_index=True)
    idx = res.index

    rng = np.random.default_rng(seed)
    requests = []
    for _ in range(n_requests):
        m = int(rng.integers(4, q_max + 1))
        requests.append(pts[rng.integers(0, len(pts), m)] + rng.normal(
            scale=0.3 * eps, size=(m, sc.d)))

    def run_stream() -> float:
        srv = ClusterServer(idx, slots=4)
        for q in requests:
            srv.submit(q)
        t0 = time.perf_counter()
        srv.run()
        dt = time.perf_counter() - t0
        return srv.summary()["queries"] / dt

    run_stream()                                  # warm (jit, caches)
    was_enabled = obs.enabled()
    obs.disable()
    qps_off = max(run_stream() for _ in range(reps))
    obs.enable(clear=True)
    qps_on = max(run_stream() for _ in range(reps))
    events = len(obs.get_tracer().snapshot_events())
    if not was_enabled:
        obs.disable()
    ratio = qps_on / qps_off if qps_off else 0.0

    rows = [
        dict(bench="obs_overhead", tracing="off", scenario=scenario,
             n=n, requests=n_requests, queries_per_s=round(qps_off, 1)),
        dict(bench="obs_overhead", tracing="on", scenario=scenario,
             n=n, requests=n_requests, queries_per_s=round(qps_on, 1),
             span_events=events, ratio_vs_off=round(ratio, 4)),
    ]
    return rows, ratio
