"""Churn-plane benchmark: steady-state mixed predict/insert/delete
traffic against the fitted index vs a refit per batch (the
BENCH_5.json perf-trajectory artifact).

The delta engine exists so that *mutating* traffic -- TTL expiry, GDPR
erasure, sliding-window streams -- does not cost a refit; this bench
quantifies that at paper scale (n = 1e5 blobs by default):

* ``fit``            -- one ``cluster(..., return_index=True)`` run.
* ``warm_graph``     -- the first mutation, which pays the one-time
                        lazy merge-graph build (reported separately so
                        the steady state is not polluted by it).
* ``churn_step``     -- warm latency of one mixed traffic batch:
                        70% predicts / 20% inserts / 10% deletes of a
                        ``batch``-sized request budget, all applied to
                        the live index (deletes draw from the live-id
                        pool, so clusters shrink, split and demote).
* ``refit_baseline`` -- what the same batch costs without the delta
                        engine: a full ``cluster()`` over the final
                        surviving set (the only exact alternative).

The headline check -- steady-state churn step >= 10x faster than a
refit per batch -- gates the run.
"""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np


def bench_churn(n: int = 100_000, scenario: str = "blobs-2d",
                engine: str = "grit", batch: int = 2048,
                steps: int = 6, seed: int = 0) -> List[Dict]:
    """Rows for the churn bench (see module docstring)."""
    from repro.data.scenarios import get_scenario
    from repro.engine import cluster

    sc = get_scenario(scenario)
    # same occupancy-preserving eps rescale as bench_distance_plane
    eps = sc.eps * (sc.n / n) ** (1.0 / sc.d)
    pts = sc.points(n=n)
    rng = np.random.default_rng(seed)
    rows: List[Dict] = []

    t0 = time.perf_counter()
    res = cluster(pts, eps, sc.min_pts, engine=engine, return_index=True)
    t_fit = time.perf_counter() - t0
    idx = res.index
    rows.append(dict(bench="churn", op="fit", scenario=scenario, n=n,
                     d=sc.d, engine=engine, seconds=round(t_fit, 4),
                     clusters=res.n_clusters, grids=idx.num_grids))

    n_pred = int(0.7 * batch)
    n_ins = int(0.2 * batch)
    n_del = batch - n_pred - n_ins

    def queries(m):
        near = pts[rng.integers(0, n, int(0.8 * m))] + rng.normal(
            scale=0.3 * eps, size=(int(0.8 * m), sc.d))
        far = rng.uniform(pts.min() - 5 * eps, pts.max() + 5 * eps,
                          size=(m - int(0.8 * m), sc.d))
        return np.concatenate([near, far])

    # the first mutation pays the lazy merge-graph build: isolate it
    t0 = time.perf_counter()
    idx.insert(queries(8))
    t_warm = time.perf_counter() - t0
    rows.append(dict(bench="churn", op="warm_graph", scenario=scenario,
                     n=n, d=sc.d, engine=engine,
                     seconds=round(t_warm, 4),
                     merge_edges=int(len(idx.merge_edges))))
    idx.predict(queries(n_pred))             # warm the predict plane too

    alive = idx.arrival_live()
    step_times = []
    deleted_total = demoted_total = 0
    for _ in range(steps):
        q = queries(n_pred)
        ins = queries(n_ins)
        kill = rng.choice(alive, size=n_del, replace=False)
        t0 = time.perf_counter()
        idx.predict(q)
        idx.insert(ins)
        st = idx.delete(kill)
        step_times.append(time.perf_counter() - t0)
        deleted_total += st["deleted"]
        demoted_total += st["demoted"]
        alive = idx.arrival_live()
    # steady state: drop the slowest step (stray compaction / cache
    # effects), report the median of the rest
    t_step = float(np.median(sorted(step_times)[:-1])) \
        if len(step_times) > 1 else step_times[0]

    # baseline: the same traffic without the delta engine is a full
    # cluster() refit over the surviving set per batch
    surv = idx.points_arrival()
    t0 = time.perf_counter()
    base_res = cluster(surv, eps, sc.min_pts, engine=engine)
    t_refit = time.perf_counter() - t0
    got = idx.labels_arrival()
    agree = float(np.mean((got >= 0) == (base_res.labels >= 0)))
    rows.append(dict(bench="churn", op="churn_step", scenario=scenario,
                     n=n, n_live=idx.n_live, d=sc.d, engine=engine,
                     batch=batch, predicts=n_pred, inserts=n_ins,
                     deletes=n_del, steps=steps,
                     seconds=round(t_step, 5),
                     seconds_max=round(float(np.max(step_times)), 5),
                     ops_per_s=round(batch / t_step, 1),
                     deleted_total=deleted_total,
                     demoted_total=demoted_total,
                     border_noise_agreement_vs_refit=round(agree, 4),
                     speedup_vs_refit=round(t_refit / t_step, 1)))
    rows.append(dict(bench="churn", op="refit_baseline",
                     scenario=scenario, n=idx.n_live, d=sc.d,
                     engine=engine, seconds=round(t_refit, 4)))
    return rows
