"""Device-pipeline benchmarks: in-graph DBSCAN + kernel micro-benches.

These measure the jitted XLA path on whatever backend is present (CPU
here, TPU on deployment).  The Pallas kernels run in interpret mode on
CPU, so their numbers here are correctness-path only -- the TPU roofline
story lives in EXPERIMENTS.md §Roofline.
"""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np
import jax
import jax.numpy as jnp

from repro.data.seed_spreader import seed_spreader
from repro.core.device_dbscan import device_dbscan, GritCaps
from repro.kernels import ops, ref


def _timeit(fn, *args, repeat: int = 3):
    fn(*args)                       # compile
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best


def bench_device_dbscan(n: int = 2048, d: int = 3) -> List[Dict]:
    pts = jnp.asarray(seed_spreader(n, d, variant="simden", restarts=6,
                                    seed=0), jnp.float32)
    caps = GritCaps(grid_cap=512, frontier_cap=256, k_cap=48, c_cap=1024,
                    m_cap=1024, pair_cap=4096, grid_block=64,
                    pair_block=512)
    f = jax.jit(lambda p: device_dbscan(p, 4000.0, 8, caps))
    t = _timeit(f, pts)
    return [dict(bench="device_dbscan", n=n, d=d, seconds=round(t, 4),
                 us_per_point=round(t / n * 1e6, 2))]


def bench_distance_plane(ns=(10_000, 100_000),
                         scenarios=("blobs-2d", "uniform-dense-2d"),
                         min_pts: int = 64, reps: int = 2) -> List[Dict]:
    """Naive-broadcast vs kernelized device pipeline (the PR 2 tentpole
    comparison behind BENCH_2.json).

    eps is scaled by (n_ref/n)^(1/d) so per-grid occupancy -- and with
    it the candidate-set structure -- stays that of the catalogue
    scenario as n grows.  MinPts sits at the paper's experimental scale
    (GriT-DBSCAN's own experiments run MinPts up to 100), where the
    core/border distance plane dominates the pipeline; at the
    catalogue's MinPts ~ 6 the plane is <1% of runtime and the planes
    tie.  (At that MinPts the scaled uniform box sits below the density
    threshold and comes out all-noise -- deliberately kept: it is the
    worst case for the MinPts early exit and the best case for the
    padding-tail skip.)  Both planes run the *same* adaptive caps; the timed quantity
    is the warm jitted pipeline (the steady-state serving cost), and
    cluster/noise counts are recorded to confirm the planes agree.
    """
    from repro.data.scenarios import get_scenario
    from repro.engine import adaptive_device_dbscan

    rows = []
    for name in scenarios:
        sc = get_scenario(name)
        for n in ns:
            eps = sc.eps * (sc.n / n) ** (1.0 / sc.d)
            pts = sc.points(n=n)
            pj = jnp.asarray(pts, jnp.float32)
            for plane, uk in (("naive", False), ("kernelized", True)):
                res, attempts = adaptive_device_dbscan(
                    pj, eps, min_pts, use_kernels=uk)
                # the attempt trail records every GritCaps field, so the
                # final attempt reconstructs the exact jit key
                caps = GritCaps(**attempts[-1]["caps"])
                best = float("inf")
                for _ in range(reps):
                    t0 = time.perf_counter()
                    jax.block_until_ready(
                        device_dbscan(pj, eps, min_pts, caps).labels)
                    best = min(best, time.perf_counter() - t0)
                lab = np.asarray(res.labels)
                rows.append(dict(
                    bench="kernel_vs_naive", scenario=name, n=n, d=sc.d,
                    min_pts=min_pts, eps=round(eps, 2), plane=plane,
                    seconds=round(best, 4),
                    clusters=int(len(np.unique(lab[lab >= 0]))),
                    noise=int((lab < 0).sum()),
                    backend=jax.default_backend()))
    return rows


def bench_pairwise_kernels(m: int = 512, n: int = 512, d: int = 3
                           ) -> List[Dict]:
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(m, d)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    rows = []
    for name, fn in [
        ("eps_count_ref", lambda: ref.eps_count(a, b, 1.0)),
        ("eps_count_kernel", lambda: ops.eps_count(a, b, 1.0)),
        ("row_min_ref", lambda: ref.row_min(a, b)),
        ("row_min_kernel", lambda: ops.row_min(a, b)),
    ]:
        t = _timeit(jax.jit(fn))
        rows.append(dict(bench="pairwise_kernel", name=name, m=m, n=n,
                         d=d, seconds=round(t, 5)))
    return rows


def bench_lm_step(arch: str = "qwen2-1.5b") -> List[Dict]:
    from repro.configs import get_config
    from repro.models import init_params, loss_fn
    from repro.train import (TrainCfg, make_train_step, init_state,
                             get_optimizer)

    cfg = get_config(arch, smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    tcfg = TrainCfg()
    opt = get_optimizer("adamw")
    step = jax.jit(make_train_step(cfg, tcfg, opt, lambda s: 1e-3))
    state = init_state(cfg, tcfg, opt, params)
    B, S = 4, 64
    batch = {"tokens": jnp.zeros((B, S + 1), jnp.int32)}
    state, _ = step(state, batch)          # compile
    t0 = time.perf_counter()
    reps = 3
    for _ in range(reps):
        state, m = step(state, batch)
    jax.block_until_ready(m["loss"])
    dt = (time.perf_counter() - t0) / reps
    return [dict(bench="lm_smoke_step", arch=arch, batch=B, seq=S,
                 seconds=round(dt, 4),
                 tokens_per_s=round(B * S / dt, 1))]
