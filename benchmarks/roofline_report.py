"""Turn dry-run JSON into the EXPERIMENTS.md §Roofline table.

Adds the MODEL_FLOPS column: 6*N*D for training (N = params, MoE: active
params; D = tokens), 2*N*D for prefill, 2*N*B for one decode step --
divided by chip count -- and the usefulness ratio MODEL/HLO that catches
remat/rectangular-attention waste.

    PYTHONPATH=src python -m benchmarks.roofline_report results/dryrun_baseline.json
"""

from __future__ import annotations

import argparse
import json
import sys


def model_flops_per_chip(arch: str, shape: str, kind: str,
                         chips: int) -> float:
    from repro.configs import get_config, get_shape
    from repro.models import count_params, active_params

    cfg = get_config(arch)
    sc = get_shape(shape)
    n_act = active_params(cfg)
    if kind == "train":
        toks = sc.seq_len * sc.global_batch
        return 6.0 * n_act * toks / chips
    if kind == "prefill":
        toks = sc.seq_len * sc.global_batch
        return 2.0 * n_act * toks / chips
    # decode: one token per sequence
    return 2.0 * n_act * sc.global_batch / chips


def fmt(x: float) -> str:
    return f"{x:.3e}"


def build_table(records, mesh_filter: str = "16x16"):
    lines = []
    hdr = ("| arch | shape | t_compute | t_memory | t_coll | bound | "
           "MODEL_FLOPs/chip | HLO/MODEL | note |")
    lines.append(hdr)
    lines.append("|" + "---|" * 9)
    for r in records:
        if r.get("mesh") != mesh_filter:
            continue
        if r.get("status") == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | -- | -- | -- | "
                         f"skip | -- | -- | {r['reason'][:40]} |")
            continue
        if r.get("status") != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | -- | -- | -- | "
                         f"FAILED | -- | -- | {r.get('error', '')[:40]} |")
            continue
        rt = r["roofline"]
        try:
            mf = model_flops_per_chip(r["arch"], r["shape"],
                                      r.get("kind", "train"), r["chips"])
            ratio = r["flops_per_chip"] / mf if mf else float("nan")
            mf_s, ratio_s = fmt(mf), f"{ratio:.2f}"
        except Exception:
            mf_s, ratio_s = "--", "--"
        note = ""
        if rt["dominant"] == "memory":
            note = "attn/logit buffer traffic"
        elif rt["dominant"] == "collective":
            note = "gather/reduce traffic"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {fmt(rt['t_compute'])} | "
            f"{fmt(rt['t_memory'])} | {fmt(rt['t_collective'])} | "
            f"{rt['dominant']} | {mf_s} | {ratio_s} | {note} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("json_path")
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args()
    with open(args.json_path) as f:
        records = json.load(f)
    print(build_table(records, args.mesh))


if __name__ == "__main__":
    main()
