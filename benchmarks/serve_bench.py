"""Serving-plane benchmark: predict throughput + insert latency vs the
fit-and-forget baseline (the BENCH_3.json perf-trajectory artifact).

The fitted ``GritIndex`` exists so that serving a query batch does NOT
cost a refit; this bench quantifies exactly that at paper scale
(n = 1e5 blobs by default):

* ``fit``            -- one ``cluster(..., return_index=True)`` run.
* ``predict_batch``  -- warm latency of one batched point-query call
                        against the fitted index (the serving hot path).
* ``refit_baseline`` -- what the same query batch costs without the
                        index: a full ``cluster()`` over fit ∪ batch
                        (the only exact alternative).
* ``insert_batch``   -- micro-batch incremental insert latency.

The headline check -- batched predict >= 10x faster than a refit per
query batch -- gates the run.
"""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np


def _query_mix(rng: np.random.Generator, base: np.ndarray, eps: float,
               n: int) -> np.ndarray:
    """Serving-shaped queries: mostly on-cluster, some far-field."""
    d = base.shape[1]
    n_near = int(0.8 * n)
    near = base[rng.integers(0, len(base), n_near)] + rng.normal(
        scale=0.3 * eps, size=(n_near, d))
    far = rng.uniform(base.min() - 5 * eps, base.max() + 5 * eps,
                      size=(n - n_near, d))
    return np.concatenate([near, far])


def bench_serve(n: int = 100_000, scenario: str = "blobs-2d",
                engine: str = "grit", q_batch: int = 2048,
                insert_m: int = 256, insert_steps: int = 4,
                reps: int = 3, seed: int = 0) -> List[Dict]:
    """Rows for the serve bench (see module docstring)."""
    from repro.data.scenarios import get_scenario
    from repro.engine import cluster

    sc = get_scenario(scenario)
    # same occupancy-preserving eps rescale as bench_distance_plane
    eps = sc.eps * (sc.n / n) ** (1.0 / sc.d)
    pts = sc.points(n=n)
    rng = np.random.default_rng(seed)
    rows: List[Dict] = []

    t0 = time.perf_counter()
    res = cluster(pts, eps, sc.min_pts, engine=engine, return_index=True)
    t_fit = time.perf_counter() - t0
    idx = res.index
    rows.append(dict(bench="serve", op="fit", scenario=scenario, n=n,
                     d=sc.d, engine=engine, seconds=round(t_fit, 4),
                     clusters=res.n_clusters,
                     grids=idx.num_grids))

    q = _query_mix(rng, pts, eps, q_batch)
    idx.predict(q)                           # warm (jit for kernel mode)
    t_pred = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        labels = idx.predict(q)
        t_pred = min(t_pred, time.perf_counter() - t0)

    # baseline: serving the same batch without an index is a full
    # cluster() over fit ∪ batch
    union = np.concatenate([pts, q])
    t0 = time.perf_counter()
    base_res = cluster(union, eps, sc.min_pts, engine=engine)
    t_refit = time.perf_counter() - t0
    agree = float(np.mean(
        (labels >= 0) == (base_res.labels[n:] >= 0)))
    rows.append(dict(bench="serve", op="predict_batch", scenario=scenario,
                     n=n, d=sc.d, engine=engine, q=q_batch,
                     seconds=round(t_pred, 5),
                     queries_per_s=round(q_batch / t_pred, 1),
                     noise=int((labels < 0).sum()),
                     border_noise_agreement_vs_refit=round(agree, 4),
                     speedup_vs_refit=round(t_refit / t_pred, 1)))
    rows.append(dict(bench="serve", op="refit_baseline", scenario=scenario,
                     n=n + q_batch, d=sc.d, engine=engine,
                     seconds=round(t_refit, 4)))

    ins_times = []
    for t in range(insert_steps):
        batch = _query_mix(rng, pts, eps, insert_m)
        t0 = time.perf_counter()
        st = idx.insert(batch)
        ins_times.append(time.perf_counter() - t0)
    rows.append(dict(bench="serve", op="insert_batch", scenario=scenario,
                     n=n, d=sc.d, engine=engine, m=insert_m,
                     batches=insert_steps,
                     seconds_mean=round(float(np.mean(ins_times)), 5),
                     seconds_max=round(float(np.max(ins_times)), 5),
                     newly_core_last=st["newly_core"]))

    snap = idx.snapshot()
    rows.append(dict(bench="serve", op="snapshot", scenario=scenario,
                     n=idx.n, d=sc.d, engine=engine,
                     bytes=int(sum(v.nbytes for v in snap.values()))))
    return rows
