"""Device-resident serving benchmark: identical mixed traffic replayed
against a host-path and a device-path :class:`ClusterServer` (the
BENCH_6.json artifact).

The device serving plane (``GritIndex.ensure_device_state``) keeps the
CSR-sorted points, core/alive flags and merge-edge arrays resident as
donated device buffers and runs predict + the delta engine's
core-recompute / merge re-decision through flat guard-band kernels; the
host numpy path stays the reference.  This bench quantifies both claims
at once:

* ``fit``    -- one ``cluster(..., return_index=True)`` run; the fitted
               index is snapshot-cloned so both servers start from the
               *same* bits.
* ``host``   -- wall time serving ``steps`` pre-scripted mixed waves
               (predict / insert / delete) on the numpy path.
* ``device`` -- the same waves, byte-identical traffic, on the
               device-resident path; reports the per-step
               ``kernel_s`` / ``pack_s`` split from the step log, the
               throughput ratio against the host row, and ``exact``:
               every predict label stream *and* the final
               ``labels_arrival()`` must be bitwise equal to the host
               server's.

Warmup waves (same traffic generator, separate draw) are served first
on each path and excluded from timing: they pay jit compilation and
saturate the pow2 upload-bucket set, which is steady-state-irrelevant
one-time cost.  The headline checks -- device throughput >= host and
``exact`` -- gate the run in ``benchmarks.run``.
"""

from __future__ import annotations

import io
import time
from typing import Dict, List

import numpy as np


def _script_traffic(pts, eps, d, rng, waves, n_pred, n_ins, n_del,
                    alive, next_id):
    """Pre-script ``waves`` mixed waves of traffic.

    Both servers must observe *identical* requests, so the kill ids are
    drawn against a simulated alive set (initially the fitted arrival
    ids; inserts extend it) rather than against either live index.
    Returns (script, alive, next_id) so warmup and timed traffic chain.
    """
    n = len(pts)
    lo, hi = pts.min() - 5 * eps, pts.max() + 5 * eps

    def points(m):
        near_m = int(0.8 * m)
        near = pts[rng.integers(0, n, near_m)] + rng.normal(
            scale=0.3 * eps, size=(near_m, d))
        far = rng.uniform(lo, hi, size=(m - near_m, d))
        return np.concatenate([near, far])

    script = []
    for _ in range(waves):
        ins = points(n_ins)
        kill = rng.choice(len(alive), size=n_del, replace=False)
        kill_ids = np.asarray([alive[k] for k in kill], np.int64)
        keep = np.ones(len(alive), bool)
        keep[kill] = False
        alive = [a for a, k in zip(alive, keep) if k] + \
            list(range(next_id, next_id + n_ins))
        next_id += n_ins
        script.append(dict(queries=points(n_pred), inserts=ins,
                           kills=kill_ids))
    return script, alive, next_id


def _serve_wave(server, wave, reqs_per_wave, labels):
    """Serve one scripted wave; appends predict labels, returns wall s."""
    q = wave["queries"]
    per = len(q) // reqs_per_wave
    t0 = time.perf_counter()
    rids = [server.submit(q[i * per:(i + 1) * per])
            for i in range(reqs_per_wave)]
    server.submit_insert(wave["inserts"])
    server.submit_delete(wave["kills"])
    done = {r.rid: r for r in server.run()}
    labels.extend(done[rid].labels for rid in rids)
    return time.perf_counter() - t0


def bench_serve_device(n: int = 60_000, scenario: str = "blobs-2d",
                       batch: int = 2048, steps: int = 8,
                       warmup: int = 6, seed: int = 0) -> List[Dict]:
    """Rows for the device-serving bench (see module docstring)."""
    from repro.data.scenarios import get_scenario
    from repro.engine import cluster
    from repro.index import GritIndex
    from repro.serve.driver import ClusterServer

    sc = get_scenario(scenario)
    # same occupancy-preserving eps rescale as bench_churn
    eps = sc.eps * (sc.n / n) ** (1.0 / sc.d)
    pts = sc.points(n=n)
    rows: List[Dict] = []

    t0 = time.perf_counter()
    res = cluster(pts, eps, sc.min_pts, engine="grit", return_index=True)
    t_fit = time.perf_counter() - t0
    res.index.ensure_merge_graph()       # one-time lazy build, pre-bench
    buf = io.BytesIO()
    res.index.save(buf)
    rows.append(dict(bench="serve_device", op="fit", scenario=scenario,
                     n=n, d=sc.d, seconds=round(t_fit, 4),
                     clusters=res.n_clusters,
                     grids=res.index.num_grids))

    n_pred = int(0.85 * batch)
    n_ins = int(0.10 * batch)
    n_del = batch - n_pred - n_ins
    reqs = 4                              # predict requests per wave
    n_pred -= n_pred % reqs

    # identical scripted traffic for both paths: warmup waves (untimed,
    # pay compilation + bucket saturation) chained into timed waves
    rng = np.random.default_rng(seed)
    alive, nxt = list(range(n)), n
    warm_script, alive, nxt = _script_traffic(
        pts, eps, sc.d, rng, warmup, n_pred, n_ins, n_del, alive, nxt)
    script, _, _ = _script_traffic(
        pts, eps, sc.d, rng, steps, n_pred, n_ins, n_del, alive, nxt)

    # both servers run the same wave back to back (host first), so
    # machine-load drift across the run hits both paths equally
    results = {}
    for op, device in (("host", False), ("device", True)):
        buf.seek(0)
        idx = GritIndex.load(buf)
        srv = ClusterServer(idx, slots=reqs + 2, query_cap=n_pred // reqs,
                            mode="host" if not device else "auto",
                            device_state=device)
        results[op] = dict(index=idx, server=srv, seconds=0.0, labels=[])
    for wave in warm_script:
        for op in ("host", "device"):
            _serve_wave(results[op]["server"], wave, reqs, [])
    warm_steps = {op: len(results[op]["server"].step_log)
                  for op in results}
    for wave in script:
        for op in ("host", "device"):
            r = results[op]
            r["seconds"] += _serve_wave(r["server"], wave, reqs,
                                        r["labels"])
    for op, r in results.items():
        timed = r["server"].step_log[warm_steps[op]:]
        r["kernel_s"] = sum(s["kernel_s"] for s in timed)
        r["pack_s"] = sum(s["pack_s"] for s in timed)
        r["final"] = r["index"].labels_arrival()
        r["n_live"] = r["index"].n_live
    host, dev = results["host"], results["device"]

    exact = (len(host["labels"]) == len(dev["labels"])
             and all(np.array_equal(a, b) for a, b in
                     zip(host["labels"], dev["labels"]))
             and np.array_equal(host["final"], dev["final"]))
    ops = steps * batch
    for op in ("host", "device"):
        r = results[op]
        row = dict(bench="serve_device", op=op, scenario=scenario, n=n,
                   n_live=r["n_live"], d=sc.d, batch=batch, steps=steps,
                   warmup=warmup, predicts=n_pred, inserts=n_ins,
                   deletes=n_del, seconds=round(r["seconds"], 4),
                   ops_per_s=round(ops / r["seconds"], 1),
                   kernel_s=round(r["kernel_s"], 4),
                   pack_s=round(r["pack_s"], 4))
        if op == "device":
            row["speedup_vs_host"] = round(
                host["seconds"] / dev["seconds"], 3)
            row["exact"] = bool(exact)
        rows.append(row)
    return rows
