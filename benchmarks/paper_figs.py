"""Paper-experiment analogues (Figs 5-11 + Remark 3), CPU-scaled.

Same experimental grid as the paper (synthetic seed-spreader data,
simden/varden, d in {2,3,5,7}; runtime vs eps / MinPts / n; grid tree vs
stencil indexing; FastMerging vs baseline merging), scaled down from the
paper's 2M-10M points to CPU-friendly sizes.  The *claims* validated are
scale-free: relative ordering of engines and near-linear growth in n.

Each function returns a list of row dicts; run.py prints them as CSV.
"""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.data.seed_spreader import seed_spreader
from repro.data.scenarios import default_scenarios
from repro.core.dbscan import grit_dbscan
from repro.core.grids import build_grids
from repro.core.grid_tree import GridTree, stencil_neighbors


def _timed(fn, *args, repeat: int = 1, **kw):
    best = float("inf")
    out = None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return best, out


# -- Figs 5 & 8: runtime vs eps ---------------------------------------------

def fig_runtime_vs_eps(n: int = 8000, dims=(2, 3, 5, 7),
                       eps_grid=(2000.0, 3000.0, 4000.0, 5000.0),
                       variant: str = "varden", min_pts: int = 10
                       ) -> List[Dict]:
    rows = []
    for d in dims:
        pts = seed_spreader(n, d, variant=variant, restarts=8, seed=d)
        for eps in eps_grid:
            for engine, kw in [
                ("grit", dict(variant="grit", merge_engine="fast")),
                ("grit-ldf", dict(variant="ldf", merge_engine="fast")),
                ("stencil", dict(variant="grit", neighbor_engine="stencil",
                                 merge_engine="fast")),
                ("center-merge", dict(variant="grit", merge_engine="center")),
            ]:
                t, r = _timed(grit_dbscan, pts, eps, min_pts, **kw)
                rows.append(dict(
                    bench="fig5_runtime_vs_eps", d=d, variant=variant,
                    eps=eps, engine=engine, seconds=round(t, 4),
                    clusters=r.stats["num_clusters"],
                    merge_checks=r.stats.get("merge_checks", 0),
                    dist_evals=r.stats.get("merge_dist_evals", 0)))
    return rows


# -- Figs 6 & 9: runtime vs MinPts -------------------------------------------

def fig_runtime_vs_minpts(n: int = 8000, d: int = 3,
                          minpts_grid=(10, 25, 50, 100),
                          eps: float = 3500.0) -> List[Dict]:
    pts = seed_spreader(n, d, variant="varden", restarts=8, seed=42)
    rows = []
    for mp in minpts_grid:
        for engine, kw in [
            ("grit", dict(variant="grit")),
            ("grit-ldf", dict(variant="ldf")),
            ("stencil", dict(variant="grit", neighbor_engine="stencil")),
        ]:
            t, r = _timed(grit_dbscan, pts, eps, mp, **kw)
            rows.append(dict(bench="fig6_runtime_vs_minpts", d=d,
                             min_pts=mp, engine=engine,
                             seconds=round(t, 4),
                             clusters=r.stats["num_clusters"]))
    return rows


# -- Figs 7 & 10: scalability with n -----------------------------------------

def fig_runtime_vs_n(d: int = 3, n_grid=(2000, 4000, 8000, 16000),
                     eps: float = 3500.0, min_pts: int = 10) -> List[Dict]:
    rows = []
    for n in n_grid:
        pts = seed_spreader(n, d, variant="simden", restarts=8, seed=7)
        for engine, kw in [
            ("grit", dict(variant="grit")),
            ("grit-ldf", dict(variant="ldf")),
            ("center-merge", dict(variant="grit", merge_engine="center")),
        ]:
            t, r = _timed(grit_dbscan, pts, eps, min_pts, **kw)
            rows.append(dict(bench="fig7_runtime_vs_n", d=d, n=n,
                             engine=engine, seconds=round(t, 4),
                             sec_per_kpoint=round(t / (n / 1000), 5)))
    return rows


# -- Fig 11: grid tree vs stencil neighbor queries ----------------------------

def fig_grid_tree_vs_stencil(n: int = 20000, dims=(2, 3, 5, 7),
                             eps_grid=(1500.0, 3000.0, 6000.0)) -> List[Dict]:
    rows = []
    for d in dims:
        pts = seed_spreader(n, d, variant="varden", restarts=10, seed=d + 1)
        for eps in eps_grid:
            gi = build_grids(pts, eps)
            t_build, tree = _timed(GridTree.build, gi.ids)
            t_tree, _ = _timed(tree.query, gi.ids, include_self=False)
            t_sten, _ = _timed(stencil_neighbors, gi.ids, gi.ids,
                               include_self=False)
            rows.append(dict(bench="fig11_tree_vs_stencil", d=d, eps=eps,
                             num_grids=gi.num_grids,
                             tree_build_s=round(t_build, 4),
                             tree_query_s=round(t_tree, 4),
                             stencil_query_s=round(t_sten, 4),
                             speedup=round(t_sten / max(t_tree, 1e-9), 2)))
    return rows


# -- Remark 3: kappa stays tiny ----------------------------------------------

def bench_kappa(n: int = 8000, dims=(2, 3, 5, 7)) -> List[Dict]:
    rows = []
    for d in dims:
        pts = seed_spreader(n, d, variant="varden", restarts=8, seed=d + 9)
        r = grit_dbscan(pts, 3500.0, 10)
        rows.append(dict(bench="kappa", d=d,
                         kappa_max=r.stats.get("merge_max_iters", 0),
                         merge_calls=r.stats.get("merge_calls", 0),
                         mean_iters=round(
                             r.stats.get("merge_iters", 0)
                             / max(r.stats.get("merge_calls", 1), 1), 3)))
    return rows


# -- engine API over the shared scenario catalogue ----------------------------

def bench_engine_scenarios(engines=("grit", "grit-ldf"),
                           tag: str = None) -> List[Dict]:
    """Every engine through ``repro.engine.cluster`` on the same scenario
    catalogue the conformance tests use (repro.data.scenarios) -- the
    benchmark and the test suite share one data-generation path.

    Emits per-(scenario, engine) rows; run.py checks that all engines
    report identical cluster/noise counts per scenario (the full
    label-level equivalence lives in tests/test_conformance.py).
    """
    from repro.engine import cluster
    rows = []
    for sc in default_scenarios():
        if tag is not None and not sc.has(tag):
            continue
        pts = sc.points()
        for engine in engines:
            t, r = _timed(cluster, pts, sc.eps, sc.min_pts, engine=engine)
            rows.append(dict(
                bench="engine_scenarios", scenario=sc.name, d=sc.d,
                n=sc.n, engine=engine, seconds=round(t, 4),
                clusters=r.n_clusters, noise=r.noise_count,
                cap_retries=r.stats.get("retries", 0)))
    return rows


# -- merging engines: distance-eval pruning (paper §4.3 story) ----------------

def bench_merge_pruning(n: int = 8000, d: int = 3) -> List[Dict]:
    pts = seed_spreader(n, d, variant="varden", restarts=8, seed=3)
    rows = []
    for engine in ("fast", "center", "brute"):
        t, r = _timed(grit_dbscan, pts, 3500.0, 10, merge_engine=engine)
        rows.append(dict(bench="merge_pruning", engine=engine,
                         seconds=round(t, 4),
                         dist_evals=r.stats.get("merge_dist_evals", 0),
                         merge_checks=r.stats.get("merge_checks", 0)))
    return rows
