"""Benchmark harness entry point: ``python -m benchmarks.run [--quick]``.

One benchmark per paper table/figure (see paper_figs.py) plus the device
pipeline micro-benches.  Prints CSV rows (bench name + fields) and a
summary of the paper-claim checks:

  * GriT >= stencil-indexed engine (grid tree wins, Fig 11 / Figs 5-10),
  * GriT-LDF >= GriT at larger eps (union-find + low-density-first),
  * FastMerging prunes distance evals vs center/brute merging (§4.3),
  * near-linear scaling in n (Theorem 4),
  * kappa small (Remark 3: <= 11 in all paper experiments),
  * kernelized distance plane beats the naive broadcast plane on the
    largest blob scenario (the PR 2 perf-trajectory entry).

The kernel-vs-naive comparison is additionally written as JSON to
``--json-out`` (default ``BENCH_2.json``): the perf-trajectory artifact
CI uploads from every run.  ``--smoke`` runs *only* that comparison at
CI scale (seconds, not minutes).

``--serve`` runs the serving-plane benchmark instead (fitted-index
predict throughput + insert latency vs a full refit per query batch,
n = 1e5 blobs) and writes ``BENCH_3.json``; the >= 10x
predict-vs-refit check gates the run.

``--churn`` runs the mutation-plane benchmark (steady-state mixed
70/20/10 predict/insert/delete traffic against the fitted index vs a
full refit per batch, n = 1e5 blobs) and writes ``BENCH_5.json``; the
>= 10x churn-step-vs-refit check gates the run.

``--serve-device`` runs the device-resident serving benchmark
(identical mixed predict/insert/delete traffic replayed on the host
numpy path and the device-resident path, reporting the kernel-vs-
host-packing time split) and writes ``BENCH_6.json``; two checks gate
the run: device throughput >= host, and bitwise-equal outputs.

``--distributed`` runs the *sharded* serving-plane benchmark
(``ShardedGritIndex`` slab-routed predict/insert vs a distributed refit
per query batch, on a mesh over every visible device) and writes
``BENCH_4.json``; the >= 10x sharded-predict-vs-distributed-refit
check gates the run.  On single-device hosts it forces a 4-way host
mesh via XLA_FLAGS (set before jax is first imported, which is why the
flag must be handled before any benchmark module loads).  The same
invocation then writes ``BENCH_7.json`` (traced-fit stage attribution,
coverage >= 90%) and ``BENCH_8.json`` (warm distributed fit <= host
grit fit at equal total n, with the halo padding-waste <= 25% and
coverage checks riding along -- ROADMAP item 2's wall-clock gate).

``--rebalance`` runs the load-adaptive topology benchmark (rebalanced
vs static sharded serving on an adversarially skewed + drifting mixed
stream, plus R=2 replicated reads vs a single read+write index) and
writes ``BENCH_9.json``; four checks gate the run: rebalanced step
throughput >= 1.5x static, hot slab >= 4x median load, replicated
reads >= 1.8x single-index, and every read-out bit-identical to the
single-index reference.
"""

from __future__ import annotations

import argparse
import csv
import io
import json
import os
import sys


def _print_csv(rows) -> str:
    out = io.StringIO()
    fields = sorted({k for r in rows for k in r})
    w = csv.DictWriter(out, fieldnames=fields)
    w.writeheader()
    for r in rows:
        w.writerow(r)
    print(out.getvalue())
    return out.getvalue()


def _stamp(payload: dict) -> dict:
    """Provenance + metrics block shared by every BENCH_* artifact:
    ``meta`` (jax/device/git provenance -- what makes a perf row
    comparable across runs) and, when any instrument recorded,
    ``metrics`` (the process-wide registry snapshot: recompile
    counters, kernel dispatch/occupancy, halo census)."""
    from repro.obs import bench_meta, registry

    payload["meta"] = bench_meta()
    snap = registry().snapshot()
    if snap:
        payload["metrics"] = snap
    return payload


def _write_bench3(path: str, rows) -> bool:
    """Dump the serve rows + verdict as BENCH_3.json.

    Verdict: batched predict at the benched n is >= 10x faster than a
    full refit per query batch (the fitted-index acceptance bar)."""
    import jax

    pred = [r for r in rows if r.get("op") == "predict_batch"]
    verdict = bool(pred) and all(
        r["speedup_vs_refit"] >= 10.0 for r in pred)
    payload = {
        "bench": "BENCH_3",
        "backend": jax.default_backend(),
        "rows": rows,
        "checks": {"predict_10x_faster_than_refit_per_batch": verdict},
    }
    with open(path, "w") as f:
        json.dump(_stamp(payload), f, indent=2)
        f.write("\n")
    print(f"wrote {path} ({len(rows)} rows)")
    return verdict


def _write_bench5(path: str, rows) -> bool:
    """Dump the churn rows + verdict as BENCH_5.json.

    Verdict: a steady-state mixed predict/insert/delete step is >= 10x
    faster than a full refit per batch (the mutation-plane acceptance
    bar)."""
    import jax

    churn = [r for r in rows if r.get("op") == "churn_step"]
    verdict = bool(churn) and all(
        r["speedup_vs_refit"] >= 10.0 for r in churn)
    payload = {
        "bench": "BENCH_5",
        "backend": jax.default_backend(),
        "rows": rows,
        "checks": {"churn_step_10x_faster_than_refit_per_batch": verdict},
    }
    with open(path, "w") as f:
        json.dump(_stamp(payload), f, indent=2)
        f.write("\n")
    print(f"wrote {path} ({len(rows)} rows)")
    return verdict


def _write_bench6(path: str, rows) -> bool:
    """Dump the device-serving rows + verdict as BENCH_6.json.

    Verdict: the device-resident serving path matches or beats host
    throughput on identical mixed traffic, *and* its outputs (predict
    label streams + final ``labels_arrival``) are bitwise equal to the
    host run -- the device plane is only allowed to be a faster route
    to the same answer."""
    import jax

    dev = [r for r in rows if r.get("op") == "device"]
    ge_host = bool(dev) and all(r["speedup_vs_host"] >= 1.0 for r in dev)
    exact = bool(dev) and all(r["exact"] for r in dev)
    payload = {
        "bench": "BENCH_6",
        "backend": jax.default_backend(),
        "rows": rows,
        "checks": {"device_serve_ge_host_throughput": ge_host,
                   "device_bitwise_equal_host": exact},
    }
    with open(path, "w") as f:
        json.dump(_stamp(payload), f, indent=2)
        f.write("\n")
    print(f"wrote {path} ({len(rows)} rows)")
    return ge_host and exact


def _write_bench4(path: str, rows) -> bool:
    """Dump the distributed serve rows + verdict as BENCH_4.json.

    Verdict: slab-routed sharded predict is >= 10x faster than a full
    distributed refit per query batch (the sharded-index acceptance
    bar)."""
    import jax

    pred = [r for r in rows if r.get("op") == "predict_batch"]
    verdict = bool(pred) and all(
        r["speedup_vs_refit"] >= 10.0 for r in pred)
    payload = {
        "bench": "BENCH_4",
        "backend": jax.default_backend(),
        "devices": jax.device_count(),
        "rows": rows,
        "checks": {
            "sharded_predict_10x_faster_than_distributed_refit": verdict,
        },
    }
    with open(path, "w") as f:
        json.dump(_stamp(payload), f, indent=2)
        f.write("\n")
    print(f"wrote {path} ({len(rows)} rows)")
    return verdict


def _write_bench7(path: str, rows) -> bool:
    """Dump the traced-distributed-fit rows + verdict as BENCH_7.json.

    Verdict: the per-stage span totals of every traced fit (pack /
    transfer / halo exchange / local cluster / reconcile / unpack,
    with the recompile + padding-waste counters riding along in the
    rows and the ``metrics`` block) account for >= 90% of the
    ``dist.fit`` wall-clock -- the attribution quality bar for the
    ROADMAP item 2 (20x distributed-fit gap) investigation."""
    import jax

    traced = [r for r in rows if r.get("bench") == "traced_fit"]
    verdict = bool(traced) and all(
        r["coverage"] >= 0.9 for r in traced)
    payload = {
        "bench": "BENCH_7",
        "backend": jax.default_backend(),
        "devices": jax.device_count(),
        "rows": rows,
        "checks": {"stage_spans_cover_90pct_of_fit_wall": verdict},
    }
    with open(path, "w") as f:
        json.dump(_stamp(payload), f, indent=2)
        f.write("\n")
    print(f"wrote {path} ({len(rows)} rows)")
    return verdict


def _write_bench8(path: str, rows) -> bool:
    """Dump the dist-vs-host fit rows + verdict as BENCH_8.json.

    Verdict (ROADMAP item 2's wall-clock gate, all three together):

    * warm distributed fit <= host grit fit at equal total n on the
      forced multi-device mesh (occupancy-packed dispatch paying for
      the SPMD plane's padding + reconcile overhead);
    * traced-fit stage coverage >= 90% (the BENCH_7 attribution bar
      stays green on the same artifact);
    * ``dist.halo.padding_waste`` <= 25% (census-sized halo_cap on the
      quarter-pow2 ladder; worst boundary side vs cap)."""
    import jax

    warm = [r for r in rows if r.get("op") == "dist_fit_warm"]
    traced = [r for r in rows if r.get("op") == "dist_fit_traced"]
    wall_ok = bool(warm) and all(r["dist_over_host"] <= 1.0 for r in warm)
    cov_ok = bool(traced) and all(r["coverage"] >= 0.9 for r in traced)
    halo_ok = bool(traced) and all(
        r["halo_padding_waste"] <= 0.25 for r in traced)
    payload = {
        "bench": "BENCH_8",
        "backend": jax.default_backend(),
        "devices": jax.device_count(),
        "rows": rows,
        "checks": {
            "dist_fit_le_host_fit_at_equal_n": wall_ok,
            "stage_spans_cover_90pct_of_fit_wall": cov_ok,
            "halo_padding_waste_le_25pct": halo_ok,
        },
    }
    with open(path, "w") as f:
        json.dump(_stamp(payload), f, indent=2)
        f.write("\n")
    print(f"wrote {path} ({len(rows)} rows)")
    return wall_ok and cov_ok and halo_ok


def _write_bench9(path: str, rows) -> bool:
    """Dump the topology-rebalance + replica rows as BENCH_9.json.

    Verdict (ISSUE 10's load-adaptive topology gate, all together):

    * on the adversarially skewed + drifting mixed stream (hot slab
      >= 4x the median shard load), load-triggered split/merge
      rebalancing reaches >= 1.5x the static-topology step throughput;
    * R=2 replicated reads reach >= 1.8x the single-index read
      throughput (per-worker wall accounting);
    * every predict stream and the final ``labels_arrival`` is
      bit-identical to the static single-index reference, topology
      ops and replica replay included."""
    reb = [r for r in rows if r.get("op") == "rebalance_serving"]
    rep = [r for r in rows if r.get("op") == "replicated_reads"]
    reb_ok = bool(reb) and all(
        r["speedup_vs_static"] >= 1.5 for r in reb)
    skew_ok = bool(reb) and all(
        r["hot_over_median_load"] >= 4.0 for r in reb)
    rep_ok = bool(rep) and all(
        r["speedup_vs_single"] >= 1.8 for r in rep)
    bit_ok = (bool(reb) and bool(rep)
              and all(r["predicts_bitwise_static"]
                      and r["predicts_bitwise_rebalanced"]
                      and r["labels_bitwise_static"]
                      and r["labels_bitwise_rebalanced"] for r in reb)
              and all(r["reads_bitwise_identical"] for r in rep))
    payload = {
        "bench": "BENCH_9",
        "rows": rows,
        "checks": {
            "rebalanced_ge_1_5x_static_step_throughput": reb_ok,
            "hot_slab_ge_4x_median_load": skew_ok,
            "replicated_reads_ge_1_8x_single": rep_ok,
            "predict_and_labels_bitwise_identical": bit_ok,
        },
    }
    with open(path, "w") as f:
        json.dump(_stamp(payload), f, indent=2)
        f.write("\n")
    print(f"wrote {path} ({len(rows)} rows)")
    return reb_ok and skew_ok and rep_ok and bit_ok


def _write_bench_obs(path: str, rows, ratio: float) -> bool:
    """Dump the tracing-overhead rows + verdict as BENCH_OBS.json.

    Verdict: tracing-enabled serve throughput >= 0.9x tracing-off on
    the same stream (the obs overhead budget)."""
    import jax

    verdict = ratio >= 0.9
    payload = {
        "bench": "BENCH_OBS",
        "backend": jax.default_backend(),
        "rows": rows,
        "checks": {"tracing_on_ge_090x_tracing_off_throughput": verdict},
    }
    with open(path, "w") as f:
        json.dump(_stamp(payload), f, indent=2)
        f.write("\n")
    print(f"wrote {path} ({len(rows)} rows)")
    return verdict


def _write_bench2(path: str, rows, smoke: bool) -> bool:
    """Dump the kernel-vs-naive rows + verdict as BENCH_2.json.

    Returns the verdict: kernelized strictly faster than the naive
    broadcast on the largest-n blob scenario that ran."""
    import jax

    kv = [r for r in rows if r["bench"] == "kernel_vs_naive"]
    blobs = [r for r in kv if r["scenario"].startswith("blobs")]
    verdict = None
    if blobs:
        n_max = max(r["n"] for r in blobs)
        planes = {r["plane"]: r["seconds"] for r in blobs
                  if r["n"] == n_max}
        verdict = planes.get("kernelized", float("inf")) < planes.get(
            "naive", float("inf"))
    payload = {
        "bench": "BENCH_2",
        "smoke": smoke,
        "backend": jax.default_backend(),
        "rows": kv,
        "checks": {"kernelized_beats_naive_on_largest_blobs": verdict},
    }
    with open(path, "w") as f:
        json.dump(_stamp(payload), f, indent=2)
        f.write("\n")
    print(f"wrote {path} ({len(kv)} rows)")
    return bool(verdict)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller grids (CI-scale)")
    ap.add_argument("--smoke", action="store_true",
                    help="kernel-vs-naive distance-plane bench only "
                         "(CI smoke: seconds, not minutes); still "
                         "writes --json-out")
    ap.add_argument("--serve", action="store_true",
                    help="serving-plane bench only (fitted-index "
                         "predict/insert vs refit-per-batch); writes "
                         "BENCH_3.json")
    ap.add_argument("--serve-n", type=int, default=100_000,
                    help="fit-set size for --serve")
    ap.add_argument("--churn", action="store_true",
                    help="mutation-plane bench only (mixed 70/20/10 "
                         "predict/insert/delete traffic vs "
                         "refit-per-batch); writes BENCH_5.json")
    ap.add_argument("--churn-n", type=int, default=100_000,
                    help="fit-set size for --churn")
    ap.add_argument("--serve-device", action="store_true",
                    help="device-resident serving bench only (identical "
                         "mixed traffic on the host vs device path, "
                         "kernel-vs-packing split + bitwise exactness); "
                         "writes BENCH_6.json")
    ap.add_argument("--serve-device-n", type=int, default=60_000,
                    help="fit-set size for --serve-device")
    ap.add_argument("--serve-device-steps", type=int, default=8,
                    help="timed waves for --serve-device")
    ap.add_argument("--distributed", action="store_true",
                    help="sharded serving-plane bench only "
                         "(ShardedGritIndex predict/insert vs a "
                         "distributed refit per batch, multi-device "
                         "mesh); writes BENCH_4.json")
    ap.add_argument("--dist-n", type=int, default=50_000,
                    help="fit-set size for --distributed")
    ap.add_argument("--dist-shards", type=int, default=4,
                    help="host devices to force for --distributed when "
                         "the platform has only one")
    ap.add_argument("--trace-n", type=int, default=None,
                    help="fit-set size for the traced-fit attribution "
                         "half of --distributed (default: --dist-n)")
    ap.add_argument("--rebalance", action="store_true",
                    help="load-adaptive topology benchmark: rebalanced "
                         "vs static sharded serving on a skewed + "
                         "drifting stream, plus R=2 replicated reads; "
                         "writes BENCH_9.json")
    ap.add_argument("--obs-overhead", action="store_true",
                    help="tracing-overhead gate only (serve throughput "
                         "with tracing on vs off, BENCH_3-shaped "
                         "stream); writes BENCH_OBS.json")
    ap.add_argument("--obs-overhead-n", type=int, default=20_000,
                    help="fit-set size for --obs-overhead")
    ap.add_argument("--out", default=None)
    ap.add_argument("--json-out", default=None,
                    help="where to write the JSON artifact (default "
                         "BENCH_2.json, BENCH_3.json under --serve, or "
                         "BENCH_4.json under --distributed)")
    args = ap.parse_args()
    if args.json_out is None:
        args.json_out = ("BENCH_4.json" if args.distributed
                         else "BENCH_9.json" if args.rebalance
                         else "BENCH_5.json" if args.churn
                         else "BENCH_6.json" if args.serve_device
                         else "BENCH_3.json" if args.serve
                         else "BENCH_2.json")

    if args.distributed:
        # must run before anything imports jax: device-count flags are
        # read at first import
        if "xla_force_host_platform_device_count" not in \
                os.environ.get("XLA_FLAGS", ""):
            os.environ["XLA_FLAGS"] = (
                os.environ.get("XLA_FLAGS", "") +
                f" --xla_force_host_platform_device_count="
                f"{args.dist_shards}").strip()
        assert "jax" not in sys.modules, \
            "--distributed must configure XLA before jax is imported"
        from benchmarks import dist_bench as DS
        rows = DS.bench_dist_serve(n=args.dist_n)
        csv_text = _print_csv(rows)
        if args.out:
            with open(args.out, "w") as f:
                f.write(csv_text)
        ok = _write_bench4(args.json_out, rows)
        print(f"[{'PASS' if ok else 'FAIL'}] sharded predict >= 10x "
              f"faster than a distributed refit per query batch "
              f"(n={args.dist_n})")
        # traced-fit attribution (BENCH_7): same mesh, obs tracing on
        trows = DS.bench_traced_fit(n=args.trace_n or args.dist_n)
        _print_csv(trows)
        ok7 = _write_bench7("BENCH_7.json", trows)
        print(f"[{'PASS' if ok7 else 'FAIL'}] traced fit stage spans "
              f"cover >= 90% of the dist.fit wall-clock")
        # dist-vs-host wall-clock gate (BENCH_8): same mesh, equal n
        vrows = DS.bench_dist_vs_host(n=args.dist_n)
        _print_csv(vrows)
        ok8 = _write_bench8("BENCH_8.json", vrows)
        print(f"[{'PASS' if ok8 else 'FAIL'}] warm distributed fit <= "
              f"host grit fit at n={args.dist_n} "
              f"({args.dist_shards}-way mesh), coverage >= 90%, halo "
              f"padding waste <= 25%")
        return 0 if (ok and ok7 and ok8) else 1

    if args.rebalance:
        # host-side plane (numpy index + policy): no mesh flags needed
        from benchmarks import rebalance_bench as RB
        rows = RB.bench_rebalance()
        csv_text = _print_csv(rows)
        if args.out:
            with open(args.out, "w") as f:
                f.write(csv_text)
        ok = _write_bench9(args.json_out, rows)
        print(f"[{'PASS' if ok else 'FAIL'}] rebalanced serving >= "
              f"1.5x static topology on the skewed drifting stream, "
              f"R=2 replicated reads >= 1.8x single-index, all "
              f"read-outs bit-identical")
        return 0 if ok else 1

    if args.obs_overhead:
        from benchmarks import obs_bench as OB
        rows, ratio = OB.bench_obs_overhead(n=args.obs_overhead_n)
        _print_csv(rows)
        ok = _write_bench_obs(
            args.json_out if args.json_out != "BENCH_2.json"
            else "BENCH_OBS.json", rows, ratio)
        print(f"[{'PASS' if ok else 'FAIL'}] tracing-enabled serve "
              f"throughput >= 0.9x tracing-off (ratio {ratio:.3f})")
        return 0 if ok else 1

    if args.serve_device:
        from benchmarks import serve_device_bench as SD
        rows = SD.bench_serve_device(n=args.serve_device_n,
                                     steps=args.serve_device_steps)
        csv_text = _print_csv(rows)
        if args.out:
            with open(args.out, "w") as f:
                f.write(csv_text)
        ok = _write_bench6(args.json_out, rows)
        print(f"[{'PASS' if ok else 'FAIL'}] device-resident serving "
              f">= host throughput and bitwise-equal outputs "
              f"(n={args.serve_device_n})")
        return 0 if ok else 1

    if args.churn:
        from benchmarks import churn_bench as C
        rows = C.bench_churn(n=args.churn_n)
        csv_text = _print_csv(rows)
        if args.out:
            with open(args.out, "w") as f:
                f.write(csv_text)
        ok = _write_bench5(args.json_out, rows)
        print(f"[{'PASS' if ok else 'FAIL'}] steady-state churn step "
              f">= 10x faster than a full refit per batch "
              f"(n={args.churn_n})")
        return 0 if ok else 1

    from benchmarks import paper_figs as F
    from benchmarks import device_bench as D

    if args.serve:
        from benchmarks import serve_bench as S
        rows = S.bench_serve(n=args.serve_n)
        csv_text = _print_csv(rows)
        if args.out:
            with open(args.out, "w") as f:
                f.write(csv_text)
        ok = _write_bench3(args.json_out, rows)
        print(f"[{'PASS' if ok else 'FAIL'}] batched predict >= 10x "
              f"faster than a full refit per query batch (n="
              f"{args.serve_n})")
        return 0 if ok else 1

    if args.smoke:
        # same MinPts operating point as the full bench so smoke rows
        # are comparable entries in the perf trajectory
        rows = D.bench_distance_plane(ns=(2000, 10_000),
                                      scenarios=("blobs-2d",),
                                      min_pts=64, reps=2)
        _print_csv(rows)
        ok = _write_bench2(args.json_out, rows, smoke=True)
        # informational at smoke scale: CI-sized runs sit within
        # scheduler noise of each other, so the verdict gates only the
        # full/nightly benchmark (larger n, stable margins) -- the
        # smoke job's job is producing the artifact, not timing
        print(f"[{'PASS' if ok else 'INFO'}] kernelized plane beats "
              f"naive broadcast (largest blob run; non-gating at "
              f"smoke scale)")
        return 0

    n = 3000 if args.quick else 8000
    n_tree = 6000 if args.quick else 20000
    rows = []
    rows += F.fig_runtime_vs_eps(n=n, dims=(2, 3) if args.quick
                                 else (2, 3, 5, 7))
    rows += F.fig_runtime_vs_minpts(n=n)
    rows += F.fig_runtime_vs_n(n_grid=(1000, 2000, 4000) if args.quick
                               else (2000, 4000, 8000, 16000))
    rows += F.fig_grid_tree_vs_stencil(n=n_tree,
                                       dims=(2, 3) if args.quick
                                       else (2, 3, 5, 7))
    rows += F.bench_kappa(n=n, dims=(2, 3) if args.quick else (2, 3, 5, 7))
    rows += F.bench_merge_pruning(n=n)
    # cross-engine matrix over the shared scenario catalogue (same data
    # generation as tests/test_conformance.py); the device engine joins
    # in full runs (its CPU cost is jit compiles, not clustering)
    rows += F.bench_engine_scenarios(
        engines=("brute", "grit", "grit-ldf") if args.quick
        else ("brute", "grit", "grit-ldf", "device"))
    rows += D.bench_device_dbscan(n=1024 if args.quick else 2048)
    rows += D.bench_pairwise_kernels()
    rows += D.bench_distance_plane(
        ns=(10_000,) if args.quick else (10_000, 100_000))
    rows += D.bench_lm_step()

    # ---- CSV dump ----
    csv_text = _print_csv(rows)
    if args.out:
        with open(args.out, "w") as f:
            f.write(csv_text)

    # ---- paper-claim checks ----
    ok = True

    def check(name, cond):
        nonlocal ok
        print(f"[{'PASS' if cond else 'FAIL'}] {name}")
        ok &= bool(cond)

    # Paper Fig 11 compares on PAM4D/Farm/House (d = 4, 5, 7); at d = 2
    # the stencil is a trivial 5x5 and both engines are at ms noise
    # scale, so the query-level claim is checked at d >= 3.
    tree = [r for r in rows if r["bench"] == "fig11_tree_vs_stencil"
            and r["d"] >= 3]
    check("grid tree faster than stencil at d>=3 (Fig 11)",
          all(r["tree_query_s"] <= r["stencil_query_s"] for r in tree))

    # The stencil engine's candidate set is (2*ceil(sqrt(d))+1)^d -- the
    # paper's win grows with d; at d<=3 both engines are sub-millisecond
    # and the comparison is noise, so the pipeline-level claim is checked
    # at d >= 5 (Fig 11 covers the query-level claim at every d).
    eps_rows = [r for r in rows if r["bench"] == "fig5_runtime_vs_eps"
                and r["d"] >= 5]
    by = {}
    for r in eps_rows:
        by.setdefault((r["d"], r["eps"]), {})[r["engine"]] = r["seconds"]
    grit_vs_stencil = [v["grit"] <= v["stencil"] * 1.15 for v in by.values()
                       if "grit" in v and "stencil" in v]
    if grit_vs_stencil:
        check("GriT <= stencil-indexed runtime at d>=5 (Figs 5-8)",
              sum(grit_vs_stencil) >= 0.8 * len(grit_vs_stencil))

    merge = {r["engine"]: r for r in rows
             if r["bench"] == "merge_pruning"}
    check("FastMerging prunes distance evals vs brute merging (§4.3)",
          merge["fast"]["dist_evals"] < 0.5 * merge["brute"]["dist_evals"])

    scal = [r for r in rows if r["bench"] == "fig7_runtime_vs_n"
            and r["engine"] == "grit"]
    if len(scal) >= 2:
        per_k = [r["sec_per_kpoint"] for r in sorted(scal,
                                                     key=lambda r: r["n"])]
        check("near-linear scaling in n (Theorem 4): sec/kpoint drift < 3x",
              per_k[-1] <= 3.0 * max(per_k[0], 1e-9))

    kap = [r for r in rows if r["bench"] == "kappa"]
    check("kappa <= 11 (Remark 3)", all(r["kappa_max"] <= 11 for r in kap))

    # kernelized vs naive distance plane (PR 2 tentpole): the kernel
    # route must beat the naive broadcast on the largest blob scenario,
    # and both planes must report identical cluster/noise counts
    ok_kernel = _write_bench2(args.json_out, rows, smoke=False)
    check("kernelized plane beats naive broadcast (largest blob run)",
          ok_kernel)
    # the two planes sum d2 in different orders (direct vs aa+bb-2ab on
    # re-centered coords), and the rescaled bench parameters carry none
    # of the catalogue's engineered decision margins -- so a knife-edge
    # point may legitimately flip by 1 ulp.  Cluster counts must match
    # exactly; noise counts get a 0.2% tolerance for such flips.
    kv = {}
    for r in rows:
        if r["bench"] == "kernel_vs_naive":
            kv.setdefault((r["scenario"], r["n"]), {})[r["plane"]] = r
    check("distance planes agree on cluster/noise counts",
          bool(kv) and all(
              v["naive"]["clusters"] == v["kernelized"]["clusters"]
              and abs(v["naive"]["noise"] - v["kernelized"]["noise"])
              <= max(1, int(0.002 * v["naive"]["n"]))
              for v in kv.values()))

    # every engine must report identical cluster/noise counts on every
    # scenario (Theorem 4 exactness; label-level equivalence is enforced
    # by tests/test_conformance.py)
    scen = {}
    for r in rows:
        if r["bench"] == "engine_scenarios":
            scen.setdefault(r["scenario"], set()).add(
                (r["clusters"], r["noise"]))
    check("engines agree on the scenario matrix (Theorem 4)",
          bool(scen) and all(len(v) == 1 for v in scen.values()))
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
