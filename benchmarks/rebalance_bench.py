"""BENCH_9: load-adaptive shard topology + replicated reads.

Two serving-plane gates (ISSUE 10 acceptance):

**Part A -- rebalanced vs static topology.**  The adversarial stream
is skewed + drifting: a narrow insert/query window sweeps one long
slab of the fitted index, depositing jittered copies of fit-time core
points (bounded jitter keeps every label decision bit-identical to a
never-sharded single index -- the correctness reference).  The slab
topology was count-balanced at fit time, so the hot slab balloons:
the delta engine's mutation cost has an O(n_shard) re-splice term,
and every step pays it on the ballooned shard.  The rebalancer splits
the hot slab as the load concentrates, bounding the per-step touch to
the window's footprint (window + ghost bands + one sub-slab) instead
of the whole slab extent -- that extent-over-footprint ratio is the
mechanism, and the gate asks for >= 1.5x steady-state step throughput
with every predict stream and the final ``labels_arrival``
bit-identical to the single-index reference.

**Part B -- replicated reads.**  Epoch-structured read-heavy traffic
(one mutation batch, then many read batches) against one index vs a
primary + R=2 :class:`~repro.index.ReplicaIndex`.  The single index
serializes reads behind writes: wall = T_write + T_read.  Replicas
catch up by replaying the primary's mutation log (cost ~= T_write)
then each serves half the reads; with per-worker wall accounting
(workers run on their own cores; the epoch pipeline overlaps the
primary's next write with replica serving) the system wall is
max(T_write, T_replay + T_read/2).  Read-heavy traffic (T_read >>
T_write) pushes the throughput ratio toward R; the gate asks >= 1.8x
at R=2, with every replica read bit-identical to the single index.
"""

from __future__ import annotations

import time
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

EPS_A, MIN_PTS = 0.15, 6
HOT_LEN = 24.0          # hot slab extent in x0 (the static pathology)
WINDOW = 1.0            # drifting hot-window width

# The mechanism, quantitatively: a static hot slab pays the delta
# engine's O(n_shard) re-splice over the slab's full extent every
# step, while a split topology pays it only over the insert window's
# footprint (window + ghost bands + ~one sub-slab).  The steady-state
# win is ~ extent / footprint, degraded by the density-proportional
# decide/merge work that ghost copies duplicate -- so the geometry
# wants a LONG hot slab, a NARROW window, and a small eps (thin ghost
# bands, few candidates per grid).


def _part_a_base(rng) -> np.ndarray:
    """One long hot block (becomes slab 0: count-balancing puts 1/4 of
    the points there) + three cold blobs."""
    hot = np.column_stack([rng.uniform(0.0, HOT_LEN, 45000),
                           rng.uniform(-10.0, 10.0, 45000)])
    cold = [np.column_stack([rng.uniform(c, c + 6.0, 45000),
                             rng.uniform(-8.0, 8.0, 45000)])
            for c in (32.0, 42.0, 52.0)]
    return np.concatenate([hot] + cold)


def _mk_stream(rng, base, hot_core, S, m, mq):
    """S steps of (insert batch, query batch): inserts jitter fit-time
    core points inside a drifting window (85% of queries too), so no
    step ever mints a fresh cluster id -- the bit-identity regime."""
    xh = base[hot_core, 0]
    out = []
    for s in range(S):
        w = 1.5 + (HOT_LEN - 3.0) * ((s * 0.37) % 1.0)
        win = hot_core[(xh >= w - WINDOW / 2) & (xh <= w + WINDOW / 2)]
        b = base[rng.choice(win, m)] + rng.normal(
            scale=0.3 * EPS_A, size=(m, 2))
        mh = int(mq * 0.7)
        qh = base[rng.choice(win, mh)] + rng.normal(
            scale=0.4 * EPS_A, size=(mh, 2))
        qr = base[rng.integers(0, len(base), mq - mh)] + rng.normal(
            scale=0.4 * EPS_A, size=(mq - mh, 2))
        out.append((b, np.concatenate([qh, qr])))
    return out


def _slab_loads(idx, ins_stats, pred_stats) -> Optional[np.ndarray]:
    """The serve driver's slab-load signal: owned routed queries +
    mutated rows per shard (what the ``serve.slab.load`` gauges carry)."""
    K = int(getattr(idx, "num_shards", 0))
    if not K:
        return None
    load = np.zeros(K, np.float64)
    owned = pred_stats.get("owned_per_shard")
    if owned is not None:
        load[:len(owned)] += owned
    for s in ins_stats.get("per_shard", ()):
        if s["shard"] < K:
            load[s["shard"]] += s["own"] + s["ghost"]
    return load


def _run_stream(idx, warm, meas, rb=None):
    """Serve warm + measured phases; returns (t_warm, t_meas, predict
    outputs, hot/median ratios over the measured phase)."""
    t_warm = t_meas = 0.0
    preds: List[np.ndarray] = []
    hot_over_med: List[float] = []
    for phase, stream in (("warm", warm), ("meas", meas)):
        for b, q in stream:
            t0 = time.perf_counter()
            ist = idx.insert(b)
            pst: Dict[str, Any] = {}
            preds.append(idx.predict(q, stats=pst))
            load = _slab_loads(idx, ist, pst)
            if load is not None:
                if phase == "meas":
                    hot_over_med.append(
                        float(load.max()) / max(float(np.median(load)),
                                                1e-9))
                if rb is not None:
                    rb.observe(load)
                    rb.maybe_rebalance(idx)
            dt = time.perf_counter() - t0
            if phase == "warm":
                t_warm += dt
            else:
                t_meas += dt
    return t_warm, t_meas, preds, hot_over_med


def bench_rebalance_serving(*, warm_steps: int = 24, warm_m: int = 30000,
                            meas_steps: int = 20, meas_m: int = 600,
                            mq: int = 50, seed: int = 0,
                            n_shards: int = 4) -> List[Dict[str, Any]]:
    """Part A: static vs rebalanced sharded serving on the skewed +
    drifting stream, with a single-index bit-identity reference."""
    from repro.dist.rebalance import RebalancePolicy, Rebalancer
    from repro.index import fit_index, fit_sharded

    rng = np.random.default_rng(seed)
    base = _part_a_base(rng)
    single = fit_index(base, EPS_A, MIN_PTS, engine="grit")
    hot_core = np.flatnonzero(single.core_arrival()[:45000])
    warm = _mk_stream(rng, base, hot_core, warm_steps, warm_m, mq)
    meas = _mk_stream(rng, base, hot_core, meas_steps, meas_m, mq)
    served = meas_steps * (meas_m + mq)   # rows+queries, measured phase

    _, t_single, p_ref, _ = _run_stream(single, warm, meas)

    static = fit_sharded(base, EPS_A, MIN_PTS, n_shards=n_shards)
    tw_s, t_static, p_s, hot_med = _run_stream(static, warm, meas)

    reb = fit_sharded(base, EPS_A, MIN_PTS, n_shards=n_shards)
    # cold_factor=0: the adversarial window keeps the hot trigger
    # saturated, so a nonzero merge threshold would thrash
    # (merge-coldest frees capacity, split-hottest immediately spends
    # it); the warm phase must SETTLE the topology so the measured
    # phase is steady-state serving, not op transients
    rb = Rebalancer(RebalancePolicy(period=2, max_shards=14,
                                    hot_factor=2.0, cold_factor=0.0))
    tw_r, t_reb, p_r, _ = _run_stream(reb, rb=rb, warm=warm, meas=meas)

    bit_static = all(np.array_equal(a, b) for a, b in zip(p_ref, p_s))
    bit_reb = all(np.array_equal(a, b) for a, b in zip(p_ref, p_r))
    labels_static = np.array_equal(single.labels_arrival(),
                                   static.labels_arrival())
    labels_reb = np.array_equal(single.labels_arrival(),
                                reb.labels_arrival())
    return [{
        "op": "rebalance_serving",
        "n_base": int(len(base)),
        "n_final": int(single.n_live),
        "warm_steps": warm_steps, "meas_steps": meas_steps,
        "warm_static_s": round(tw_s, 4), "warm_rebalanced_s": round(tw_r, 4),
        "meas_single_s": round(t_single, 4),
        "meas_static_s": round(t_static, 4),
        "meas_rebalanced_s": round(t_reb, 4),
        "static_rows_per_s": round(served / t_static, 1),
        "rebalanced_rows_per_s": round(served / t_reb, 1),
        "speedup_vs_static": round(t_static / t_reb, 3),
        "hot_over_median_load": round(float(np.mean(hot_med)), 1),
        "shards_static": int(static.num_shards),
        "shards_rebalanced": int(reb.num_shards),
        "topology_ops": len(rb.history),
        "max_shard_n_static": int(max(s.n for s in static.shards)),
        "max_shard_n_rebalanced": int(max(s.n for s in reb.shards)),
        "predicts_bitwise_static": bool(bit_static),
        "predicts_bitwise_rebalanced": bool(bit_reb),
        "labels_bitwise_static": bool(labels_static),
        "labels_bitwise_rebalanced": bool(labels_reb),
    }]


def bench_replicated_reads(*, n: int = 40000, epochs: int = 6,
                           write_m: int = 30, read_batches: int = 40,
                           read_q: int = 400, r: int = 2,
                           seed: int = 0) -> List[Dict[str, Any]]:
    """Part B: R replicated readers vs one read+write index, per-worker
    wall accounting on epoch-structured read-heavy traffic."""
    from repro.index import fit_index, make_replicas

    eps, mp = 0.6, 6
    rng = np.random.default_rng(seed)
    base = np.concatenate([
        rng.normal((c * 12.0, 0.0), 2.0, (n // 4, 2)) for c in range(4)])
    single = fit_index(base, eps, mp, engine="grit")
    primary = fit_index(base, eps, mp, engine="grit")
    replicas = make_replicas(primary, r, auto_catch_up=False)
    # steady-state measurement: the one-time lazy merge-graph build
    # (paid by the first mutation on each index) is warmup, not traffic
    for idx in (single, primary, *replicas):
        (idx.index if hasattr(idx, "index") else idx).ensure_merge_graph()
    core = np.flatnonzero(single.core_arrival())

    stream: List[Tuple[np.ndarray, List[np.ndarray]]] = []
    for _ in range(epochs):
        w = base[rng.choice(core, write_m)] + rng.normal(
            scale=0.3 * eps, size=(write_m, 2))
        reads = [base[rng.integers(0, len(base), read_q)] + rng.normal(
            scale=0.4 * eps, size=(read_q, 2)) for _ in range(read_batches)]
        stream.append((w, reads))

    wall_single = 0.0
    # per-worker walls: primary (writes) + each replica (replay + its
    # half of the reads); the epoch wall on separate cores is the max
    wall_primary = 0.0
    wall_replica = np.zeros(r)
    wall_rep_total = 0.0
    bitwise = True
    for w_batch, reads in stream:
        t0 = time.perf_counter()
        single.insert(w_batch)
        t_w = time.perf_counter() - t0
        t0 = time.perf_counter()
        ref_out = [single.predict(q) for q in reads]
        t_r = time.perf_counter() - t0
        wall_single += t_w + t_r

        t0 = time.perf_counter()
        primary.insert(w_batch)
        wall_primary += time.perf_counter() - t0
        walls = []
        for i, rep in enumerate(replicas):
            t0 = time.perf_counter()
            rep.catch_up()
            share = reads[i::r]
            out = [rep.predict(q) for q in share]
            walls.append(time.perf_counter() - t0)
            wall_replica[i] += walls[-1]
            bitwise &= all(np.array_equal(a, b)
                           for a, b in zip(out, ref_out[i::r]))
        wall_rep_total += max(walls)

    reads_total = epochs * read_batches * read_q
    return [{
        "op": "replicated_reads",
        "n_base": int(len(base)), "replicas": r, "epochs": epochs,
        "reads": reads_total,
        "wall_single_s": round(wall_single, 4),
        "wall_primary_s": round(wall_primary, 4),
        "wall_replica_max_s": round(float(wall_replica.max()), 4),
        "wall_replicated_s": round(wall_rep_total, 4),
        "single_reads_per_s": round(reads_total / wall_single, 1),
        "replicated_reads_per_s": round(reads_total / wall_rep_total, 1),
        "speedup_vs_single": round(wall_single / wall_rep_total, 3),
        "reads_bitwise_identical": bool(bitwise),
        "replica_lag_after": [int(rep.lag) for rep in replicas],
    }]


def bench_rebalance(**kw) -> List[Dict[str, Any]]:
    """Both BENCH_9 parts, one row each."""
    return (bench_rebalance_serving(seed=kw.get("seed", 0)) +
            bench_replicated_reads(seed=kw.get("seed", 0)))
