"""Distributed serving-plane benchmark: sharded predict/insert vs the
distributed-refit baseline (the BENCH_4.json perf-trajectory artifact).

The sharded index exists so that serving a query batch against a
*distributed* fit does NOT cost a mesh-wide refit; this bench
quantifies exactly that:

* ``fit``            -- one distributed SPMD fit (adaptive caps) +
                        the host-side shard build (``fit_sharded``).
* ``predict_batch``  -- warm latency of one slab-routed batched
                        predict against the sharded index (the
                        distributed serving hot path; queries bucketed
                        by owning slab, cut-band queries consulting
                        both neighbors).
* ``refit_baseline`` -- what the same query batch costs without the
                        index: a full distributed ``cluster()`` over
                        fit ∪ batch (the only exact alternative).
* ``insert_batch``   -- micro-batch incremental insert latency
                        (touched shards + edge re-reconciliation).
* ``snapshot``       -- serialized size of the whole sharded state.

The headline check -- sharded predict >= 10x faster than a distributed
refit per query batch -- gates the run.  Needs a multi-device mesh
(``benchmarks/run.py --distributed`` forces host devices before jax
imports when the platform has only one).
"""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np


def _query_mix(rng: np.random.Generator, base: np.ndarray, eps: float,
               cuts: np.ndarray, n: int) -> np.ndarray:
    """Serving-shaped queries: mostly on-cluster, some far-field, and a
    slab-band slice pinned to the cut coordinates (the routing path a
    single-host bench never exercises)."""
    d = base.shape[1]
    n_near = int(0.6 * n)
    n_band = int(0.25 * n) if len(cuts) else 0
    n_far = n - n_near - n_band
    near = base[rng.integers(0, len(base), n_near)] + rng.normal(
        scale=0.3 * eps, size=(n_near, d))
    far = rng.uniform(base.min() - 5 * eps, base.max() + 5 * eps,
                      size=(n_far, d))
    parts = [near, far]
    if n_band:
        band = base[rng.integers(0, len(base), n_band)].copy()
        band[:, 0] = (cuts[rng.integers(0, len(cuts), n_band)]
                      + rng.uniform(-2.0, 2.0, n_band) * eps)
        parts.append(band)
    return np.concatenate(parts)


def bench_dist_serve(n: int = 50_000, scenario: str = "blobs-2d",
                     q_batch: int = 2048, insert_m: int = 256,
                     insert_steps: int = 3, reps: int = 3,
                     seed: int = 0) -> List[Dict]:
    """Rows for the distributed serve bench (see module docstring)."""
    import jax
    from repro.data.scenarios import get_scenario
    from repro.engine import cluster
    from repro.index import ShardedGritIndex, fit_sharded

    mesh = jax.make_mesh((jax.device_count(),), ("shard",))
    n_shards = int(mesh.devices.size)
    sc = get_scenario(scenario)
    # same occupancy-preserving eps rescale as bench_distance_plane
    eps = sc.eps * (sc.n / n) ** (1.0 / sc.d)
    pts = sc.points(n=n)
    rng = np.random.default_rng(seed)
    rows: List[Dict] = []

    t0 = time.perf_counter()
    sidx = fit_sharded(pts, eps, sc.min_pts, mesh=mesh)
    t_fit = time.perf_counter() - t0
    rows.append(dict(bench="dist_serve", op="fit", scenario=scenario,
                     n=n, d=sc.d, n_shards=n_shards,
                     seconds=round(t_fit, 4),
                     shards=sidx.num_shards, grids=sidx.num_grids))

    q = _query_mix(rng, pts, eps, sidx.cuts, q_batch)
    stats: Dict = {}
    sidx.predict(q, mode="host", stats=stats)          # warm
    t_pred = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        labels = sidx.predict(q, mode="host")
        t_pred = min(t_pred, time.perf_counter() - t0)

    # baseline: serving the same batch without the index is a full
    # distributed cluster() over fit ∪ batch
    union = np.concatenate([pts, q])
    t0 = time.perf_counter()
    base_res = cluster(union, eps, sc.min_pts, engine="distributed",
                       mesh=mesh)
    t_refit = time.perf_counter() - t0
    agree = float(np.mean((labels >= 0) == (base_res.labels[n:] >= 0)))
    rows.append(dict(bench="dist_serve", op="predict_batch",
                     scenario=scenario, n=n, d=sc.d, n_shards=n_shards,
                     q=q_batch, seconds=round(t_pred, 5),
                     queries_per_s=round(q_batch / t_pred, 1),
                     multi_routed=int(stats.get("multi_routed", 0)),
                     noise=int((labels < 0).sum()),
                     border_noise_agreement_vs_refit=round(agree, 4),
                     speedup_vs_refit=round(t_refit / t_pred, 1)))
    rows.append(dict(bench="dist_serve", op="refit_baseline",
                     scenario=scenario, n=n + q_batch, d=sc.d,
                     n_shards=n_shards, seconds=round(t_refit, 4)))

    ins_times, unions = [], 0
    for _ in range(insert_steps):
        batch = _query_mix(rng, pts, eps, sidx.cuts, insert_m)
        t0 = time.perf_counter()
        st = sidx.insert(batch)
        ins_times.append(time.perf_counter() - t0)
        unions += st["reconcile_unions"]
    rows.append(dict(bench="dist_serve", op="insert_batch",
                     scenario=scenario, n=n, d=sc.d, n_shards=n_shards,
                     m=insert_m, batches=insert_steps,
                     seconds_mean=round(float(np.mean(ins_times)), 5),
                     seconds_max=round(float(np.max(ins_times)), 5),
                     reconcile_unions=unions))

    snap = sidx.snapshot()
    rows.append(dict(bench="dist_serve", op="snapshot",
                     scenario=scenario, n=sidx.n, d=sc.d,
                     n_shards=n_shards,
                     bytes=int(sum(v.nbytes for v in snap.values()))))
    assert ShardedGritIndex.restore(snap).num_shards == sidx.num_shards
    return rows


def bench_traced_fit(n: int = 50_000, scenario: str = "blobs-2d",
                     seed: int = 0,
                     trace_out: str = "BENCH_7_trace.json") -> List[Dict]:
    """Traced distributed fit: where does the fit wall-clock go?

    Runs ``cluster(engine="distributed")`` with ``repro.obs`` tracing
    on (the staged SPMD step: pack / halo exchange / local cluster /
    reconcile as separately-synced spans), once cold (jit compiles
    included) and once warm, and attributes each fit's wall-clock to
    its stages plus the recompile and padding-waste counters -- the
    instrumentation ROADMAP item 2 (the ~20x distributed-fit gap)
    needs.  Exports the cold run's Perfetto-loadable Chrome trace to
    ``trace_out`` and prints the ``repro.obs.view`` attribution table.

    Each row carries ``coverage``: the fraction of the ``dist.fit``
    span accounted for by its stage children (the >= 0.9 acceptance
    bar BENCH_7.json gates on).
    """
    import jax
    from repro import obs
    from repro.obs import view as obs_view
    from repro.data.scenarios import get_scenario
    from repro.engine import cluster

    mesh = jax.make_mesh((jax.device_count(),), ("shard",))
    n_shards = int(mesh.devices.size)
    sc = get_scenario(scenario)
    eps = sc.eps * (sc.n / n) ** (1.0 / sc.d)
    pts = sc.points(n=n)

    obs.enable(clear=True)
    obs.install_jax_hooks()
    reg = obs.registry()
    rows: List[Dict] = []
    compiles_before = sum(obs.recompile_counts().values())
    cold_events = None
    for phase in ("cold", "warm"):
        obs.get_tracer().clear()
        t0 = time.perf_counter()
        cluster(pts, eps, sc.min_pts, engine="distributed", mesh=mesh)
        wall = time.perf_counter() - t0
        events = obs.get_tracer().snapshot_events()
        if phase == "cold":
            cold_events = events
        att = obs_view.attribution(events, root="dist.fit")
        compiles = sum(obs.recompile_counts().values())
        snap = reg.snapshot()
        row = dict(bench="traced_fit", op=phase, scenario=scenario,
                   n=n, d=sc.d, n_shards=n_shards,
                   cluster_wall_s=round(wall, 4),
                   fit_wall_s=round(att["wall_us"] / 1e6, 4),
                   coverage=round(att["coverage"], 4),
                   recompiles=compiles - compiles_before,
                   halo_padding_waste=round(
                       snap.get("dist.halo.padding_waste",
                                {}).get("value", 0.0), 4),
                   pack_padding_waste=round(
                       snap.get("dist.pack.padding_waste",
                                {}).get("value", 0.0), 4))
        for name, us in att["children"].items():
            row[f"stage_{name.rsplit('.', 1)[-1]}_s"] = round(us / 1e6, 4)
        rows.append(row)
        compiles_before = compiles
    obs.export.write_chrome_trace(
        trace_out, cold_events, metrics=reg.snapshot(),
        meta=obs.bench_meta())
    print(f"wrote {trace_out} ({len(cold_events)} events; open in "
          f"ui.perfetto.dev)")
    print(obs_view.render(cold_events, reg.snapshot(), obs.bench_meta(),
                          root="dist.fit"))
    obs.disable()
    return rows


def bench_dist_vs_host(n: int = 50_000, scenario: str = "blobs-2d",
                       reps: int = 5, seed: int = 0) -> List[Dict]:
    """Distributed fit vs host grit fit at equal total n (BENCH_8).

    ROADMAP item 2's wall-clock gate: after the occupancy-packed
    dispatch + census-sized halo work, a warm distributed fit on a
    forced multi-device mesh must come in at or under the *host* grit
    fit on the same points -- i.e. the SPMD plane pays for itself even
    when every "device" timeshares one CPU.  Both sides are measured
    as the min over ``reps`` warm repetitions (the box is noisy; min
    is the stable statistic).  A traced warm fit rides along to carry
    the BENCH_7-style coverage and the ``dist.halo.padding_waste``
    gauge (worst-boundary-side census vs halo_cap -- the <= 25%
    over-provisioning bound of the quarter-pow2 cap ladder).
    """
    import jax
    from repro import obs
    from repro.obs import view as obs_view
    from repro.data.scenarios import get_scenario
    from repro.engine import cluster

    mesh = jax.make_mesh((jax.device_count(),), ("shard",))
    n_shards = int(mesh.devices.size)
    sc = get_scenario(scenario)
    eps = sc.eps * (sc.n / n) ** (1.0 / sc.d)   # occupancy-preserving
    pts = sc.points(n=n)
    rows: List[Dict] = []

    def timed(fn):
        t0 = time.perf_counter()
        fn()
        return time.perf_counter() - t0

    # host baseline: dynamic-shape host pipeline, warm = min over reps
    # (first call includes one-off jit of the small device helpers)
    cluster(pts, eps, sc.min_pts, engine="grit")
    host_s = min(timed(lambda: cluster(pts, eps, sc.min_pts,
                                       engine="grit"))
                 for _ in range(reps))
    rows.append(dict(bench="dist_vs_host", op="host_grit_fit",
                     scenario=scenario, n=n, d=sc.d, n_shards=1,
                     wall_s=round(host_s, 4)))

    # distributed: cold (compiles + caps estimation), then warm reps
    cold_s = timed(lambda: cluster(pts, eps, sc.min_pts,
                                   engine="distributed", mesh=mesh))
    dist_s = min(timed(lambda: cluster(pts, eps, sc.min_pts,
                                       engine="distributed", mesh=mesh))
                 for _ in range(reps))
    ratio = dist_s / host_s if host_s else float("inf")
    rows.append(dict(bench="dist_vs_host", op="dist_fit_cold",
                     scenario=scenario, n=n, d=sc.d, n_shards=n_shards,
                     wall_s=round(cold_s, 4)))
    rows.append(dict(bench="dist_vs_host", op="dist_fit_warm",
                     scenario=scenario, n=n, d=sc.d, n_shards=n_shards,
                     wall_s=round(dist_s, 4),
                     dist_over_host=round(ratio, 4)))

    # traced warm fit: coverage + halo padding-waste ride-alongs
    obs.enable(clear=True)
    reg = obs.registry()
    traced_s = timed(lambda: cluster(pts, eps, sc.min_pts,
                                     engine="distributed", mesh=mesh))
    att = obs_view.attribution(obs.get_tracer().snapshot_events(),
                               root="dist.fit")
    snap = reg.snapshot()
    obs.disable()
    rows.append(dict(
        bench="dist_vs_host", op="dist_fit_traced",
        scenario=scenario, n=n, d=sc.d, n_shards=n_shards,
        wall_s=round(traced_s, 4),
        coverage=round(att["coverage"], 4),
        halo_padding_waste=round(
            snap.get("dist.halo.padding_waste", {}).get("value", 0.0), 4),
        halo_fill=round(
            snap.get("dist.halo.fill", {}).get("value", 0.0), 4)))
    return rows
