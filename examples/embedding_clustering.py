"""The paper's "very large database" setting inside an LM stack:
cluster sequence embeddings with exact GriT-DBSCAN.

    PYTHONPATH=src python examples/embedding_clustering.py

Pipeline (DESIGN.md §4): an LM from the zoo embeds token sequences
(mean-pooled final hidden states) -> PCA to low-d (the paper's own
PAM4D preprocessing: Remark 3 restricts the method to low dimensions)
-> GriT-DBSCAN groups them.  Sequences are drawn from k distinct Markov
sources; the discovered clusters should recover the sources.
"""

import numpy as np


def main():
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.models import init_params, forward
    from repro.engine import cluster
    from repro.data.tokens import TokenPipeline

    cfg = get_config("qwen2-1.5b", smoke=True).with_overrides(
        dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))

    # --- build sequences from k distinct sources -------------------------
    # each source walks a Markov chain over its own (near-disjoint)
    # 24-token slice of the vocab -> separable sequence embeddings
    k_sources, per_source, S = 4, 60, 64
    seqs, labels_true = [], []
    for s in range(k_sources):
        pipe = TokenPipeline(cfg.vocab_size, S - 1, per_source,
                             seed=1000 + 7 * s, latent_k=24)
        seqs.append(pipe.next_batch()["tokens"])
        labels_true += [s] * per_source
    tokens = np.concatenate(seqs)
    labels_true = np.asarray(labels_true)

    # --- embed: mean-pooled final hidden state ----------------------------
    print(f"embedding {len(tokens)} sequences with {cfg.name}...")
    emb_fn = jax.jit(lambda p, t: forward(cfg, p, {"tokens": t})[0].mean(1))
    embs = []
    for i in range(0, len(tokens), 32):
        embs.append(np.asarray(emb_fn(params, jnp.asarray(tokens[i:i + 32]))))
    embs = np.concatenate(embs).astype(np.float64)

    # --- PCA to low-d (paper Remark 3: method is for low-d data) ----------
    d_low = 3
    x = embs - embs.mean(0)
    _, _, vt = np.linalg.svd(x, full_matrices=False)
    proj = x @ vt[:d_low].T
    # normalize to the paper's [0, 1e5] domain
    proj = (proj - proj.min(0)) / (proj.max(0) - proj.min(0) + 1e-12) * 1e5

    # --- exact GriT-DBSCAN (simple eps sweep, classic DBSCAN practice) ----
    min_pts = 8
    best = None
    for eps in (3000.0, 5000.0, 8000.0, 12000.0, 18000.0):
        r_try = cluster(proj, eps, min_pts, engine="grit")
        score = (r_try.n_clusters, -r_try.noise_count)
        if r_try.noise_count <= 0.25 * len(proj) and \
                (best is None or score > best[0]):
            best = (score, eps, r_try)
    assert best is not None, "no eps produced a low-noise clustering"
    _, eps, r = best
    found = r.n_clusters
    print(f"GriT-DBSCAN (eps={eps:.0f}): {found} clusters, "
          f"{int((r.labels < 0).sum())} noise points, "
          f"kappa_max={r.stats.get('merge_max_iters', 0)}")

    # --- cluster purity vs the true sources --------------------------------
    purity = 0
    for c in range(found):
        members = labels_true[r.labels == c]
        if len(members):
            purity += np.bincount(members).max()
    purity /= max((r.labels >= 0).sum(), 1)
    print(f"cluster purity vs true sources: {purity:.3f}")
    assert found >= 2, "expected to discover cluster structure"
    assert purity > 0.8, f"purity too low: {purity}"
    print("done.")


if __name__ == "__main__":
    main()
