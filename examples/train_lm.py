"""End-to-end driver: train a ~100M-param qwen2-family LM.

    PYTHONPATH=src python examples/train_lm.py            # ~100M, 300 steps
    PYTHONPATH=src python examples/train_lm.py --quick    # CI-scale

Exercises the full production path: config -> init -> sharded train step
(jit) -> fault-tolerant loop with async checkpoints -> resume.  On a TPU
pod the same script scales out via the mesh/sharding policy; on CPU the
--quick preset keeps it to a couple of minutes.
"""

import argparse
import time


def lm_100m():
    """~100M params: qwen2-style dense decoder."""
    from repro.models.config import LMConfig
    return LMConfig(
        name="lm-100m", family="dense",
        num_layers=10, d_model=640, num_heads=10, num_kv_heads=2,
        head_dim=64, d_ff=2560, vocab_size=32000,
        qkv_bias=True, tie_embeddings=True, rope_theta=1e6, ce_chunk=128,
    )


def lm_10m():
    from repro.models.config import LMConfig
    return LMConfig(
        name="lm-10m", family="dense",
        num_layers=4, d_model=256, num_heads=4, num_kv_heads=2,
        head_dim=64, d_ff=1024, vocab_size=8192,
        qkv_bias=True, tie_embeddings=True, rope_theta=1e6, ce_chunk=64,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--seq-len", type=int, default=None)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    from repro.models import init_params, count_params
    from repro.train import (TrainCfg, make_train_step, init_state,
                             get_optimizer, warmup_cosine)
    from repro.train import checkpoint as ckpt
    from repro.data.tokens import TokenPipeline
    from repro.launch.cluster import run_resilient, StepGuard

    cfg = lm_10m() if args.quick else lm_100m()
    steps = args.steps or (60 if args.quick else 300)
    batch = args.batch or (8 if args.quick else 16)
    seq = args.seq_len or (128 if args.quick else 512)

    n = count_params(cfg)
    print(f"model {cfg.name}: {n/1e6:.1f}M params, "
          f"{steps} steps @ batch {batch} x seq {seq}")

    tcfg = TrainCfg(optimizer="adamw", peak_lr=3e-3,
                    warmup_steps=max(steps // 10, 1), total_steps=steps)
    opt = get_optimizer(tcfg.optimizer)
    lr_fn = warmup_cosine(tcfg.peak_lr, tcfg.warmup_steps, tcfg.total_steps)
    step_fn = jax.jit(make_train_step(cfg, tcfg, opt, lr_fn))

    pipe = TokenPipeline(cfg.vocab_size, seq, batch, seed=0)
    params = init_params(cfg, jax.random.PRNGKey(0))
    state = init_state(cfg, tcfg, opt, params)
    if args.resume and ckpt.latest_step(args.ckpt_dir) is not None:
        state, extra = ckpt.restore(args.ckpt_dir, state)
        if "pipeline" in extra:
            pipe = TokenPipeline.from_state(cfg.vocab_size, seq, batch,
                                            extra["pipeline"])
        print(f"resumed at step {int(state['step'])}")

    t0 = time.time()
    losses = []

    def on_metrics(i, m):
        losses.append(float(m["loss"]))
        if i % 10 == 0:
            toks = batch * seq * (i - int(losses and 0))
            print(f"step {i:4d}  loss {losses[-1]:.4f}  "
                  f"lr {float(m['lr']):.2e}  "
                  f"tok/s {batch * seq * i / (time.time() - t0):,.0f}",
                  flush=True)

    def next_batch():
        return {"tokens": jnp.asarray(pipe.next_batch()["tokens"])}

    state, ran = run_resilient(
        state, step_fn, next_batch, ckpt_dir=args.ckpt_dir,
        num_steps=steps, ckpt_every=max(steps // 5, 10),
        guard=StepGuard(factor=100.0),
        pipeline_state=lambda: {"pipeline": pipe.state()},
        on_metrics=on_metrics)

    print(f"finished {ran} steps in {time.time()-t0:.1f}s; "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    assert losses[-1] < losses[0], "loss did not decrease"


if __name__ == "__main__":
    main()
