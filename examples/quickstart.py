"""Quickstart: exact GriT-DBSCAN on seed-spreader data, three engines.

    PYTHONPATH=src python examples/quickstart.py

Runs the paper-faithful host pipeline, the LDF variant, and the fully
in-graph device pipeline on the same data and verifies all three produce
DBSCAN-equivalent clusterings.
"""

import numpy as np
import jax.numpy as jnp

from repro.data.seed_spreader import seed_spreader
from repro.core.dbscan import grit_dbscan, brute_dbscan
from repro.core.device_dbscan import device_dbscan, GritCaps
from repro.core.validate import assert_dbscan_equivalent


def main():
    n, d = 4000, 3
    eps, min_pts = 3500.0, 10
    print(f"generating {n} points in {d}-D (seed-spreader, varden)...")
    pts = seed_spreader(n, d, variant="varden", restarts=6, seed=0)

    print("GriT-DBSCAN (paper Algorithm 6, grid tree + FastMerging):")
    r = grit_dbscan(pts, eps, min_pts)
    s = r.stats
    print(f"  clusters={s['num_clusters']}  grids={s['num_grids']}  "
          f"kappa_max={s.get('merge_max_iters', 0)}  "
          f"merge dist evals={s.get('merge_dist_evals', 0):,}")
    print(f"  time: partition {s['t_partition']*1e3:.1f}ms  "
          f"neighbors {s['t_neighbors']*1e3:.1f}ms  "
          f"cores {s['t_cores']*1e3:.1f}ms  merge {s['t_merge']*1e3:.1f}ms  "
          f"assign {s['t_assign']*1e3:.1f}ms")

    print("GriT-DBSCAN-LDF (union-find, low-density-first):")
    r_ldf = grit_dbscan(pts, eps, min_pts, variant="ldf")
    print(f"  clusters={r_ldf.stats['num_clusters']}  "
          f"merge checks={r_ldf.stats['merge_checks']} "
          f"(vs {s['merge_checks']} for BFS order)")

    print("device pipeline (single jitted XLA program):")
    caps = GritCaps(grid_cap=1024, frontier_cap=256, k_cap=48, c_cap=2048,
                    m_cap=2048, pair_cap=8192, grid_block=128,
                    pair_block=512)
    r_dev = device_dbscan(jnp.asarray(pts, jnp.float32), eps, min_pts, caps)
    print(f"  clusters={int(r_dev.num_clusters)}  "
          f"overflow={bool(r_dev.overflow)}")

    print("validating all three against the O(n^2) oracle...")
    ref = brute_dbscan(pts, eps, min_pts)
    assert_dbscan_equivalent(pts, eps, min_pts, ref, r.labels)
    assert_dbscan_equivalent(pts, eps, min_pts, ref, r_ldf.labels)
    assert_dbscan_equivalent(pts, eps, min_pts, ref,
                             np.asarray(r_dev.labels))
    print("all equivalent. done.")


if __name__ == "__main__":
    main()
