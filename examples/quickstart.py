"""Quickstart: exact GriT-DBSCAN through the unified engine API.

    PYTHONPATH=src python examples/quickstart.py

One entry point (``repro.engine.cluster``) drives every backend: the
paper-faithful host pipeline, the LDF variant, and the fully in-graph
device pipeline with adaptive static caps.  All are verified equivalent
to the O(n^2) oracle.  The last sections show the fit-once / serve-many
path: ``return_index=True`` keeps the fitted ``GritIndex``, which
snapshots to flat arrays, restores in another process, and serves the
full mutation plane -- point queries, micro-batch inserts, exact
deletes and compaction -- without ever refitting; and the sharded
variant (``fit_sharded`` -> ``ShardedGritIndex``): a distributed fit
kept as per-slab index shards plus a global label map, serving
slab-routed predicts and cross-shard inserts/deletes the same way.
"""

import io
import time

import numpy as np

from repro.data.seed_spreader import seed_spreader
from repro.engine import cluster, engine_descriptions
from repro.core.validate import assert_dbscan_equivalent
from repro.index import GritIndex, ShardedGritIndex, fit_sharded


def main():
    n, d = 4000, 3
    eps, min_pts = 3500.0, 10
    print(f"generating {n} points in {d}-D (seed-spreader, varden)...")
    pts = seed_spreader(n, d, variant="varden", restarts=6, seed=0)

    print("registered engines:")
    for name, desc in engine_descriptions().items():
        print(f"  {name:12s} {desc.splitlines()[0]}")

    print("\nGriT-DBSCAN (paper Algorithm 6, grid tree + FastMerging):")
    r = cluster(pts, eps, min_pts, engine="grit")
    s = r.stats
    print(f"  clusters={r.n_clusters}  grids={s['num_grids']}  "
          f"kappa_max={s.get('merge_max_iters', 0)}  "
          f"merge dist evals={s.get('merge_dist_evals', 0):,}")
    print(f"  time: partition {s['t_partition']*1e3:.1f}ms  "
          f"neighbors {s['t_neighbors']*1e3:.1f}ms  "
          f"cores {s['t_cores']*1e3:.1f}ms  merge {s['t_merge']*1e3:.1f}ms  "
          f"assign {s['t_assign']*1e3:.1f}ms")

    print("GriT-DBSCAN-LDF (union-find, low-density-first):")
    r_ldf = cluster(pts, eps, min_pts, engine="grit-ldf")
    print(f"  clusters={r_ldf.n_clusters}  "
          f"merge checks={r_ldf.stats['merge_checks']} "
          f"(vs {s['merge_checks']} for BFS order)")

    print("device pipeline (single jitted XLA program, adaptive caps):")
    r_dev = cluster(pts, eps, min_pts, engine="device")
    trail = " -> ".join(str(a["overflow"] or "ok") for a in r_dev.attempts)
    print(f"  clusters={r_dev.n_clusters}  "
          f"cap attempts: {trail}  "
          f"(caps estimated from grid stats, no hand tuning)")

    print("validating all three against the O(n^2) oracle...")
    ref = cluster(pts, eps, min_pts, engine="brute")
    for res in (r, r_ldf, r_dev):
        assert_dbscan_equivalent(pts, eps, min_pts, ref.labels, res.labels)
    print("all equivalent.")

    print("\nfit once, serve many (the GritIndex serving plane):")
    fitted = cluster(pts, eps, min_pts, engine="grit", return_index=True)
    buf = io.BytesIO()
    fitted.index.save(buf)                # flat arrays: ships anywhere
    buf.seek(0)
    idx = GritIndex.load(buf)             # e.g. in another process
    rng = np.random.default_rng(1)
    queries = pts[rng.integers(0, n, 500)] + rng.normal(
        scale=0.2 * eps, size=(500, d))
    t0 = time.perf_counter()
    labels = idx.predict(queries)         # exact: nearest-core-within-eps
    t_pred = time.perf_counter() - t0
    print(f"  snapshot {buf.getbuffer().nbytes / 1e3:.0f}kB -> restore -> "
          f"predict 500 queries in {t_pred * 1e3:.1f}ms "
          f"({int((labels >= 0).sum())} assigned, "
          f"{int((labels < 0).sum())} noise) -- no refit")
    st = idx.insert(queries[:64])         # micro-batch incremental update
    print(f"  insert 64 points: {st['newly_core']} newly core, "
          f"{st['affected_grids']} grids recomputed, "
          f"{st['t_total'] * 1e3:.1f}ms")
    # the full mutation plane: fit -> insert -> delete -> compact.
    # deletes are by arrival id (fit points are 0..n-1, inserts append;
    # ids are never reused) and are exact even where DBSCAN is
    # non-monotone -- cutting a bridge splits the cluster, and the
    # persistent merge graph makes the component recompute cheap.
    # unknown ids are rejected, not raised (TTL races are normal).
    st = idx.delete(np.arange(n, n + 32))  # drop half the insert above
    print(f"  delete 32 points: {st['demoted']} cores demoted, "
          f"{st['changed_grids']} grids re-decided, "
          f"{st['rejected']} ids rejected, {st['t_total'] * 1e3:.1f}ms")
    st = idx.compact()                    # re-pack tombstoned rows now
    print(f"  compact: {st['removed']} rows re-packed "
          f"({idx.n_live} live); deletes also auto-compact past "
          f"{idx.compact_threshold:.0%} dead")

    print("\ndevice-resident serving (same answers, kernel hot path):")
    # keep the serving-hot arrays resident as jax buffers: predict and
    # the delta engine's hot stages run through guard-banded float32
    # kernels, with every uncertain case re-decided by the same host
    # float64 code -- outputs stay bit-identical to host serving
    # (pinned by tests/test_device_serving.py), it is purely a faster
    # route on large batches.  drop_device_state() returns to host-only.
    idx.ensure_device_state()
    stats = {}
    labels_dev = idx.predict(queries, mode="device", stats=stats)
    assert np.array_equal(labels_dev, idx.predict(queries, mode="host"))
    print(f"  predict {len(queries)} queries on the resident state: "
          f"pack {stats['t_pack'] * 1e3:.1f}ms + kernel "
          f"{stats['t_kernel'] * 1e3:.1f}ms, {stats['uncertain']} "
          f"band-uncertain queries re-decided in float64 -- labels "
          f"bit-identical to host")
    st = idx.insert(queries[64:128])      # mutations keep buffers fresh
    print(f"  insert 64 more: donated-scatter flag updates + mirror "
          f"re-ship, {st['t_total'] * 1e3:.1f}ms; benchmarks/run.py "
          f"--serve-device gates device >= host throughput (BENCH_6)")
    idx.drop_device_state()

    print("\ndistributed fit -> snapshot -> predict (the sharded plane):")
    # on a multi-device mesh pass mesh=jax.make_mesh(...) and the SPMD
    # engine fits the slabs in parallel; without one, the same serving
    # structure is built from a single-process fit
    import jax
    mesh = (jax.make_mesh((jax.device_count(),), ("shard",))
            if jax.device_count() > 1 else None)
    sidx = fit_sharded(pts, eps, min_pts, mesh=mesh, n_shards=4)
    print(f"  {sidx.num_shards} slab shards, cuts at "
          f"{np.round(sidx.cuts, 0).tolist()} (dim-0 grid lines)")
    buf = io.BytesIO()
    sidx.save(buf)                        # per-shard snapshots, one file
    buf.seek(0)
    sidx = ShardedGritIndex.load(buf)     # e.g. on the serving host
    stats = {}
    t0 = time.perf_counter()
    labels = sidx.predict(queries, stats=stats)   # slab-routed, exact
    t_pred = time.perf_counter() - t0
    print(f"  snapshot {buf.getbuffer().nbytes / 1e3:.0f}kB -> restore -> "
          f"predict {len(queries)} queries in {t_pred * 1e3:.1f}ms "
          f"({stats['multi_routed']} cut-band queries consulted both "
          f"neighbor shards)")
    st = sidx.insert(queries[:64])        # touched shards + reconcile
    print(f"  insert 64 points: shards {st['shards_touched']} touched, "
          f"{st['newly_core']} newly core, "
          f"{st['reconcile_unions']} cross-shard label unions, "
          f"{st['t_total'] * 1e3:.1f}ms")
    st = sidx.delete(np.arange(n, n + 32))  # owner + ghost copies go
    print(f"  delete 32 points: shards {st['shards_touched']} touched, "
          f"label map rebuilt from {st['reconcile_unions']} witness "
          f"unions, {st['t_total'] * 1e3:.1f}ms")
    print("done.")


if __name__ == "__main__":
    main()
