"""Batched serving example: prefill + decode with slot-based batching.

    PYTHONPATH=src python examples/serve_batch.py [--arch mixtral-8x7b]

Thin wrapper over the production serving driver (launch/serve.py) run at
smoke scale: requests with ragged prompt lengths are left-padded into a
fixed slot batch, prefetched once, then decoded step-by-step.  Uses the
SWA ring-buffer KV cache when the arch defines a window (mixtral), the
RWKV/Mamba O(1) state caches for the recurrent archs.
"""

import sys

from repro.launch import serve


if __name__ == "__main__":
    argv = sys.argv[1:]
    if not any(a.startswith("--arch") for a in argv):
        argv = ["--arch", "mixtral-8x7b"] + argv
    if "--smoke" not in argv:
        argv.append("--smoke")
    sys.argv = [sys.argv[0]] + argv
    serve.main()
